"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) decoder.

Chunked SSD algorithm (the paper's Listing 1, adapted to JAX):

  * split the sequence into chunks of length ``Q``;
  * intra-chunk: quadratic attention-like term with the decay mask
    ``L[i, j] = exp(segsum(a))`` — this is the part that maps onto the MXU;
  * inter-chunk: a per-chunk state ``(H, P, N)`` carried by an associative
    recurrence ``h_{c+1} = decay_c * h_c + B_c^T x_c`` implemented with
    ``jax.lax.associative_scan`` over chunks (log-depth, TPU-friendly)
    — this replaces the CUDA selective-scan kernel of Mamba-1.

State layout per head: (P=head_dim, N=d_state).  Decode step is the O(1)
recurrence ``h = exp(a dt) h + dt B x`` with output ``C^T h`` — SSM state plays
the role of the KV cache and never grows with sequence length (why this arch
runs the long_500k shape).

Sensitive params (A_log, dt_bias, norms) stay fp32 and are excluded from
quantization by ``core.store.default_quantize_predicate``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import (Schema, Spec, init_params, matmul, rms_norm, softmax_xent,
                     take_rows)


def mamba_schema(prefix: str, L: int, D: int, ssm, resid: float) -> Schema:
    """One stacked Mamba-2 block's parameters.

    TP layout note: the projection is SPLIT into a [z | x] tensor (both halves
    d_inner, sharded over the model axis with the split exactly on a shard
    boundary) and a small replicated [B | C | dt] tensor — a single fused
    (D, 2·Din + 2GN + H) projection puts the split points off shard
    boundaries and GSPMD emits thousands of halo collective-permutes
    (hypothesis→confirmed in EXPERIMENTS.md §Perf).  The depthwise conv is
    likewise split per channel group (mathematically identical).
    """
    Din = ssm.d_inner(D)
    H = ssm.n_heads(D)
    N = ssm.d_state
    G = 1                            # n_groups=1 for B/C (paper's MVA analogue)
    K = ssm.d_conv
    return {
        f"{prefix}/norm": Spec((L, D), ("layers", None), "ones", jnp.float32),
        f"{prefix}/in_zx": Spec((L, D, 2 * Din), ("layers", "embed", "mlp")),
        f"{prefix}/in_bcdt": Spec((L, D, 2 * G * N + H),
                                  ("layers", "embed", None)),
        f"{prefix}/conv_x_w": Spec((L, K, Din), ("layers", None, "mlp"), 0.02,
                                   jnp.float32),
        f"{prefix}/conv_x_b": Spec((L, Din), ("layers", "mlp"), "zeros",
                                   jnp.float32),
        f"{prefix}/conv_bc_w": Spec((L, K, 2 * G * N), ("layers", None, None),
                                    0.02, jnp.float32),
        f"{prefix}/conv_bc_b": Spec((L, 2 * G * N), ("layers", None), "zeros",
                                    jnp.float32),
        f"{prefix}/A_log": Spec((L, H), ("layers", "heads"), "a_log",
                                jnp.float32),
        f"{prefix}/dt_bias": Spec((L, H), ("layers", "heads"), "dt_bias",
                                  jnp.float32),
        f"{prefix}/D_skip": Spec((L, H), ("layers", "heads"), "ones",
                                 jnp.float32),
        f"{prefix}/ssm_norm": Spec((L, Din), ("layers", "mlp"), "ones",
                                   jnp.float32),
        f"{prefix}/out_proj": Spec((L, Din, D), ("layers", "mlp", "embed"),
                                   resid),
    }


def schema(cfg: ArchConfig) -> Schema:
    L, D = cfg.n_layers, cfg.d_model
    Vp = cfg.padded_vocab()
    resid = 0.02 / (2 * L) ** 0.5
    s: Schema = {
        "embed": Spec((Vp, D), ("vocab", "embed"), 0.02),
        "final_norm": Spec((D,), (None,), "ones", jnp.float32),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = Spec((D, Vp), ("embed", "vocab"), 0.02)
    s.update(mamba_schema("layers", L, D, cfg.ssm, resid))
    return s


def init(cfg: ArchConfig, key: jax.Array) -> Dict[str, jax.Array]:
    return init_params(schema(cfg), key)


def _layer_stack(params: Dict[str, Any]) -> Dict[str, Any]:
    return {k.split("/", 1)[1]: v for k, v in params.items() if k.startswith("layers/")}




def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  xBC: (B, S, C); w: (K, C); returns (y, new_state).

    ``state`` is the last K-1 inputs (B, K-1, C) for streaming decode.
    """
    B, S, C = xBC.shape
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((B, K - 1, C), xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)            # (B, S+K-1, C)
    # depthwise conv as K shifted adds — avoids conv_general for tiny K
    y = jnp.zeros((B, S, C), jnp.float32)
    for k in range(K):
        y = y + xp[:, k: k + S, :].astype(jnp.float32) * w[k].astype(jnp.float32)
    y = jax.nn.silu(y + b.astype(jnp.float32))
    new_state = xp[:, S:, :]                            # last K-1 inputs
    return y.astype(xBC.dtype), new_state


def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum a[..., j+1..i] (−inf for j > i)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int, h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x:  (B, S, H, P)   — value-like input (already gated/conv'd)
    dt: (B, S, H)      — softplus'd timestep (>0)
    A:  (H,)           — negative decay rate
    Bm: (B, S, N)      — input projection (n_groups=1, broadcast over heads)
    Cm: (B, S, N)      — output projection
    h0: (B, H, P, N)   — initial state (decode restart); None = zeros

    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if S % chunk:
        # pad with dt = 0 positions: decay exp(0) = 1 and input x*dt = 0, so the
        # padded tail neither perturbs the carried state nor the first S outputs.
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk, h0=h0)
        return y[:, :S], h
    nc = S // chunk
    f32 = jnp.float32

    xb = x.reshape(B, nc, chunk, H, P).astype(f32)
    dtb = dt.reshape(B, nc, chunk, H).astype(f32)
    Bb = Bm.reshape(B, nc, chunk, N).astype(f32)
    Cb = Cm.reshape(B, nc, chunk, N).astype(f32)

    a = dtb * A[None, None, None, :]                     # (B,nc,Q,H) log-decay
    a_hq = jnp.moveaxis(a, -1, -2)                       # (B,nc,H,Q)

    # ---- intra-chunk (quadratic, MXU-friendly) ----
    Lmat = jnp.exp(_segsum(a_hq))                        # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", Cb, Bb)       # (B,nc,Q,Q)
    M = scores[:, :, None] * Lmat                        # (B,nc,H,Q,Q)
    xdt = xb * dtb[..., None]                            # (B,nc,Q,H,P)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, xdt)

    # ---- chunk states ----
    a_cum = jnp.cumsum(a_hq, axis=-1)                    # (B,nc,H,Q)
    a_tot = a_cum[..., -1]                               # (B,nc,H)
    decay_in = jnp.exp(a_tot[..., None] - a_cum)         # (B,nc,H,Q) decay from t→end
    states = jnp.einsum("bcjn,bchj,bcjhp->bchpn", Bb, decay_in, xdt)

    # ---- inter-chunk associative recurrence: h_c = exp(a_tot_c) h_{c-1} + states_c
    decay_chunk = jnp.exp(a_tot)                         # (B,nc,H)

    def combine(left, right):
        dl, hl = left
        dr, hr = right
        return dl * dr, hr + hl * dr[..., None, None]

    d_scan, h_scan = jax.lax.associative_scan(
        combine, (jnp.moveaxis(decay_chunk, 1, 0), jnp.moveaxis(states, 1, 0)))
    h_after = jnp.moveaxis(h_scan, 0, 1)                 # (B,nc,H,P,N) state AFTER chunk c
    d_all = jnp.moveaxis(d_scan, 0, 1)                   # (B,nc,H) cumulative decay
    if h0 is not None:
        h_after = h_after + d_all[..., None, None] * h0[:, None].astype(f32)
    # state entering chunk c
    h_in = jnp.concatenate([
        (h0[:, None].astype(f32) if h0 is not None
         else jnp.zeros_like(h_after[:, :1])),
        h_after[:, :-1],
    ], axis=1)

    # ---- inter-chunk output: y_off[t] = C_t · exp(a_cum[t]) h_in
    decay_out = jnp.exp(a_cum)                           # (B,nc,H,Q)
    y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", Cb, h_in, decay_out)

    y = (y_diag + y_off).reshape(B, S, H, P)
    return y.astype(x.dtype), h_after[:, -1]


def ssd_step(x, dt, A, Bm, Cm, h):
    """O(1) decode recurrence.  x: (B,H,P); dt: (B,H); Bm/Cm: (B,N); h: (B,H,P,N)."""
    f32 = jnp.float32
    xf, dtf, Bf, Cf, hf = (t.astype(f32) for t in (x, dt, Bm, Cm, h))
    da = jnp.exp(dtf * A[None])                          # (B,H)
    h_new = hf * da[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xf * dtf[..., None], Bf)
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cf)
    return y.astype(x.dtype), h_new


def _mamba_block(cfg: ArchConfig, lp: Dict[str, Any], x: jax.Array, *,
                 conv_state=None, ssm_state=None, chunk: Optional[int] = None):
    """One mamba2 block.  Returns (out, (new_conv_state, new_ssm_state)).

    conv_state is a pair (x-channels state, BC-channels state) matching the
    split projections (see ``mamba_schema``).
    """
    ssm = cfg.ssm
    B, S, D = x.shape
    Din = ssm.d_inner(D)
    H, P, N, G = ssm.n_heads(D), ssm.head_dim, ssm.d_state, 1
    chunk = chunk or ssm.chunk

    h = rms_norm(x, lp["norm"])
    zx = matmul(h, lp["in_zx"])
    z, xs = jnp.split(zx, [Din], axis=-1)          # split ON a shard boundary
    bcdt = matmul(h, lp["in_bcdt"])                # small, replicated
    BC, dt = jnp.split(bcdt, [2 * G * N], axis=-1)
    cs_x, cs_bc = conv_state if conv_state is not None else (None, None)
    xs, new_conv_x = _causal_conv(xs, lp["conv_x_w"], lp["conv_x_b"], cs_x)
    BC, new_conv_bc = _causal_conv(BC, lp["conv_bc_w"], lp["conv_bc_b"], cs_bc)
    Bm, Cm = jnp.split(BC, [G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))        # (H,) negative

    xh = xs.reshape(B, S, H, P)
    if S == 1 and ssm_state is not None:
        y, h_new = ssd_step(xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], ssm_state)
        y = y[:, None]
    else:
        y, h_new = ssd_chunked(xh, dt, A, Bm, Cm, chunk, h0=ssm_state)
    y = y + xh * lp["D_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, Din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 lp["ssm_norm"])
    out = matmul(y, lp["out_proj"])
    return out, ((new_conv_x, new_conv_bc), h_new)


def forward(cfg: ArchConfig, params, tokens, *, unroll: int = 1,
            remat: bool = False, collect_cache: bool = False,
            chunk: Optional[int] = None):
    from repro.distributed.ctx import constrain_activation
    B, S = tokens.shape
    x = constrain_activation(take_rows(params["embed"], tokens))
    stack = _layer_stack(params)

    def body(x, lp):
        out, (cs, hs) = _mamba_block(cfg, lp, x, chunk=chunk)
        return constrain_activation(x + out), (cs, hs) if collect_cache else None

    fn = jax.checkpoint(body) if remat else body
    x, caches = jax.lax.scan(fn, x, stack, unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return x, caches


def logits_fn(cfg: ArchConfig, params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        from .layers import deq
        return matmul(x, deq(params["embed"]).T)
    return matmul(x, params["lm_head"])


def loss_fn(cfg: ArchConfig, params, batch, *, unroll: int = 1, remat: bool = True,
            q_block: int = 0, chunk: Optional[int] = None) -> jax.Array:
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    x, _ = forward(cfg, params, inp, unroll=unroll, remat=remat, chunk=chunk)
    return softmax_xent(logits_fn(cfg, params, x), labels, cfg.vocab)


# ------------------------------------------------------------------------- serving

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    ssm = cfg.ssm
    L, D = cfg.n_layers, cfg.d_model
    Din = ssm.d_inner(D)
    H, P, N, G = ssm.n_heads(D), ssm.head_dim, ssm.d_state, 1
    return {
        "conv_x": jnp.zeros((L, batch, ssm.d_conv - 1, Din), dtype),
        "conv_bc": jnp.zeros((L, batch, ssm.d_conv - 1, 2 * G * N), dtype),
        "ssm": jnp.zeros((L, batch, H, P, N), jnp.float32),
    }


def cache_specs(cfg: ArchConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    return {
        "conv_x": ("layers", "batch", None, "mlp"),
        "conv_bc": ("layers", "batch", None, None),
        "ssm": ("layers", "batch", "heads", None, None),
    }


def prefill(cfg: ArchConfig, params, tokens, *, max_len: Optional[int] = None,
            unroll: int = 1, q_block: int = 0, chunk: Optional[int] = None):
    """State cache is O(1) in sequence length — max_len is accepted for API parity."""
    B, S = tokens.shape
    x = take_rows(params["embed"], tokens)
    stack = _layer_stack(params)

    def body(x, lp):
        out, ((cx, cbc), hs) = _mamba_block(cfg, lp, x, chunk=chunk)
        return x + out, (cx, cbc, hs)

    x, (cxs, cbcs, ssms) = jax.lax.scan(body, x, stack, unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    logits = logits_fn(cfg, params, x[:, -1:, :])
    return logits, {"conv_x": cxs, "conv_bc": cbcs, "ssm": ssms}


def decode_step(cfg: ArchConfig, params, token, cache, pos, *, unroll: int = 1):
    from repro.distributed.ctx import constrain_activation
    B = token.shape[0]
    x = constrain_activation(take_rows(params["embed"], token))
    stack = _layer_stack(params)

    def body(x, xs):
        lp, cx, cbc, hs = xs
        out, ((cx, cbc), hs) = _mamba_block(cfg, lp, x, conv_state=(cx, cbc),
                                            ssm_state=hs)
        return constrain_activation(x + out), (cx, cbc, hs)

    x, (cxs, cbcs, ssms) = jax.lax.scan(
        body, x, (stack, cache["conv_x"], cache["conv_bc"], cache["ssm"]),
        unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return logits_fn(cfg, params, x), {"conv_x": cxs, "conv_bc": cbcs,
                                       "ssm": ssms}
