"""dbrx-132b — fine-grained MoE decoder (hf:databricks/dbrx-base).

[moe] 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, 16 experts top-4.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe", n_layers=40, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=10752, vocab=100352,
    moe=MoEConfig(num_experts=16, top_k=4),
    source="hf:databricks/dbrx-base (16 experts top-4, fine-grained)",
)
