"""chameleon-34b — early-fusion VLM backbone (arXiv:2405.09818).

[vlm] 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536, qk_norm.
The VQ image frontend is a stub: image tokens share the text vocabulary.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="dense", n_layers=48, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=22016, vocab=65536, qk_norm=True, frontend="vq_image",
    source="arXiv:2405.09818 (early-fusion, VQ image tokens share the text vocab)",
)
