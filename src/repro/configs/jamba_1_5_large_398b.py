"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 + MoE (arXiv:2403.19887).

[hybrid] 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536,
MoE 16e top-2 every 2nd layer; 1 attention layer per period of 8.
Sub-quadratic: runs the long_500k decode shape.
"""
from .base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=24576, vocab=65536, attn_period=8,
    moe=MoEConfig(num_experts=16, top_k=2, every_n=2),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64),
    subquadratic=True,
    source="arXiv:2403.19887 (Mamba+attn 1:7 interleave, MoE every 2nd layer)",
)
