"""Architecture + input-shape configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; input shapes are
:class:`ShapeConfig` entries.  ``registry.get(name)`` resolves ``--arch`` flags;
``reduced(cfg)`` derives the CPU-smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    shared_experts: int = 0         # always-active experts (qwen2-moe)
    every_n: int = 1                # MoE layer every n-th block (jamba: 2)
    capacity_factor: float = 1.25   # GShard dispatch capacity

    def padded_experts(self, multiple: int) -> int:
        return _round_up(self.num_experts, multiple)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256                # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_period: int = 0            # hybrid: 1 attention layer per this many layers
    enc_layers: int = 0             # encdec: encoder depth (n_layers = decoder depth)
    frontend: Optional[str] = None  # 'vq_image' | 'audio' stub note
    subquadratic: bool = False      # eligible for long_500k decode
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def padded_vocab(self, multiple: int = 512) -> int:
        return _round_up(self.vocab, multiple)

    # -- parameter counting (for 6ND roofline + Table-I-style storage reports) ------
    def param_count(self) -> int:
        return sum(int_prod(s) for s in self.param_shapes().values())

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of the routed experts)."""
        from repro.models import api
        specs = api.param_specs(self)
        total = 0
        for name, shape in self.param_shapes().items():
            n = int_prod(shape)
            # expert FFN weights carry both axes; the router ("expert" only)
            # runs for every token and stays fully counted
            if self.moe and "expert" in specs[name] \
                    and "expert_mlp" in specs[name]:
                n = n * self.moe.top_k // max(self.moe.num_experts, 1)
            total += n
        return total

    def param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        """Logical parameter shapes (mirrors models.* init exactly; asserted by tests)."""
        from repro.models import api  # local import to avoid cycles
        return api.param_shapes(self)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    def applicable(self, cfg: ArchConfig) -> bool:
        if self.name == "long_500k":
            return cfg.subquadratic
        return True


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def int_prod(shape: Tuple[int, ...]) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out
