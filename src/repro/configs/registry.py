"""Registry over the 10 assigned architectures (one module per arch, exact
configs from the assignment) + reduced smoke-test variants of the same family.

``get("--arch id")`` resolves CLI flags; ``reduced(cfg)`` derives the CPU smoke
variant (same structure, small shapes).
"""
from __future__ import annotations

from typing import Dict

from . import (chameleon_34b, command_r_plus_104b, dbrx_132b, glm4_9b,
               jamba_1_5_large_398b, mamba2_370m, qwen2_moe_a2_7b, qwen3_1_7b,
               seamless_m4t_medium, stablelm_12b)
from .base import ArchConfig, MoEConfig, SSMConfig

_MODULES = [
    chameleon_34b, seamless_m4t_medium, stablelm_12b, command_r_plus_104b,
    glm4_9b, qwen3_1_7b, jamba_1_5_large_398b, dbrx_132b, qwen2_moe_a2_7b,
    mamba2_370m,
]

ARCHS: Dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Small same-family variant for CPU smoke tests (shapes only, structure intact)."""
    kw = dict(
        name=cfg.name + "-reduced", family=cfg.family,
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 8),
        d_model=128, n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=256 if cfg.d_ff else 0, vocab=512,
        head_dim=32 if cfg.n_heads else None,
        qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        tie_embeddings=cfg.tie_embeddings, attn_period=min(cfg.attn_period, 4),
        enc_layers=min(cfg.enc_layers, 2), frontend=cfg.frontend,
        subquadratic=cfg.subquadratic, source="reduced smoke variant",
    )
    if cfg.moe:
        kw["moe"] = MoEConfig(
            num_experts=4, top_k=2, shared_experts=min(cfg.moe.shared_experts, 1),
            every_n=cfg.moe.every_n, capacity_factor=cfg.moe.capacity_factor)
    if cfg.ssm:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32)
    return ArchConfig(**kw)
