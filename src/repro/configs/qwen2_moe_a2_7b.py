"""qwen2-moe-a2.7b — shared+routed MoE (hf:Qwen/Qwen1.5-MoE-A2.7B).

[moe] 24L d_model=2048 16H (kv=16) d_ff=1408 vocab=151936, 60 routed top-4 + 4 shared.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab=151936,
    moe=MoEConfig(num_experts=60, top_k=4, shared_experts=4),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B (4 shared + 60 routed top-4)",
)
