"""seamless-m4t-medium — enc-dec multimodal backbone (arXiv:2308.11596).

[audio] 12L(+12 enc) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
Speech frontend is a stub: batches carry precomputed frame embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec", n_layers=12, enc_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206,
    frontend="audio",
    source="arXiv:2308.11596 (enc-dec backbone; speech frontend stubbed)",
)
