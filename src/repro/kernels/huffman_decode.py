"""Pallas TPU kernel: multi-stream LUT-based canonical Huffman decode.

TPU adaptation of the paper's thread-parallel decoder (§III-C).  The paper
assigns one CPU thread per encoded segment; here a *vector lane* takes that
role: a block of ``LANES`` streams advances in lock-step, one symbol per
iteration, via a gather into the canonical-code lookup table.

VMEM budget per program instance (defaults):
  * LUT: 2 x 2^12 x 4 B               =  32 KiB
  * stream block: LANES x stream_bytes = 128 x B bytes (B <= 64 KiB -> 8 MiB max;
    segment sizing keeps B ~ 10 KiB for 64k-symbol uint4 segments -> ~1.3 MiB)
  * output block: LANES x max_count x 4 B

The bit-window arithmetic matches ``core.bitstream.decode_serial`` exactly:
MSB-first within bytes, 32-bit sliding window, ``max_len``-bit peek.

The decode loop is sequential in symbols (inherent to Huffman) but the kernel
is embarrassingly parallel across stream blocks — grid dim 0 — which is how
the paper's "coarse-grained parallelism over tensors" maps onto a TPU core's
grid + lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128     # streams per program instance (one VREG row of lanes)


def _decode_kernel(mat_ref, counts_ref, lut_sym_ref, lut_len_ref, out_ref, *,
                   max_len: int, max_count: int):
    """One grid step: decode LANES streams, max_count symbols each."""
    d = mat_ref[...].astype(jnp.uint32)           # (LANES, B) stream bytes
    counts = counts_ref[...]                      # (LANES,)
    lut_sym = lut_sym_ref[...]                    # (2^max_len,)
    lut_len = lut_len_ref[...]
    mask = jnp.uint32((1 << max_len) - 1)
    lanes = jnp.arange(d.shape[0])

    def step(k, carry):
        bitpos, out = carry
        byte = (bitpos >> 3).astype(jnp.int32)
        # 32-bit window starting at byte (guard bytes make byte+3 in-bounds)
        w = (
            (d[lanes, byte] << 24)
            | (d[lanes, byte + 1] << 16)
            | (d[lanes, byte + 2] << 8)
            | d[lanes, byte + 3]
        )
        shift = (32 - max_len - (bitpos & 7)).astype(jnp.uint32)
        peek = ((w >> shift) & mask).astype(jnp.int32)
        sym = lut_sym[peek]
        ln = lut_len[peek]
        active = k < counts
        out = out.at[:, k].set(jnp.where(active, sym, 0))
        bitpos = jnp.where(active, bitpos + ln, bitpos)
        return bitpos, out

    bitpos0 = jnp.zeros((d.shape[0],), jnp.int32)
    out0 = jnp.zeros((d.shape[0], max_count), jnp.int32)
    _, out = jax.lax.fori_loop(0, max_count, step, (bitpos0, out0))
    out_ref[...] = out


def pallas_decode_supported(max_len: int = 8) -> bool:
    """Probe whether the decode kernel *compiles* on this host.

    Runs a one-stream, one-symbol decode with ``interpret=False`` and checks
    the result; any lowering/compile error (e.g. CPU-only hosts, where Pallas
    has no compiled path) makes this False.  Cached after first call — the
    backend registry consults it so ``interpret=True`` is never picked
    implicitly (it is the explicitly named ``pallas-interpret`` fallback).
    """
    key = int(max_len)
    if key in _SUPPORTED_CACHE:
        return _SUPPORTED_CACHE[key]
    try:
        import numpy as np
        from repro.core.bitstream import encode_symbols
        from repro.core.entropy import HuffmanTable
        table = HuffmanTable(np.array([1, 1], dtype=np.int64), max_len=max_len)
        stream, _ = encode_symbols(np.array([1], np.uint8), table.codes,
                                   table.lengths)
        mat = stream[None, :]
        out = decode_streams_pallas(
            jnp.asarray(mat), jnp.asarray([1], jnp.int32),
            jnp.asarray(table.lut_sym), jnp.asarray(table.lut_len),
            max_len=max_len, max_count=1, interpret=False)
        ok = int(np.asarray(out)[0, 0]) == 1
    except Exception:
        ok = False
    _SUPPORTED_CACHE[key] = ok
    return ok


_SUPPORTED_CACHE: dict = {}


@functools.partial(jax.jit,
                   static_argnames=("max_len", "max_count", "interpret"))
def decode_streams_pallas(mat: jax.Array, counts: jax.Array, lut_sym: jax.Array,
                          lut_len: jax.Array, *, max_len: int, max_count: int,
                          interpret: bool = False) -> jax.Array:
    """mat: (S, B) uint8 guard-padded streams (S % LANES == 0 after padding);
    counts: (S,) int32.  Returns (S, max_count) int32 symbols.
    """
    S, B = mat.shape
    Sp = -(-S // LANES) * LANES
    if Sp != S:
        mat = jnp.pad(mat, ((0, Sp - S), (0, 0)))
        counts = jnp.pad(counts, (0, Sp - S))
    # LUT block shape follows the array (the raw codec passes a 2^bits-entry
    # identity LUT through this same kernel; peek masking uses max_len)
    lut_size = lut_sym.shape[0]

    kernel = functools.partial(_decode_kernel, max_len=max_len,
                               max_count=max_count)
    out = pl.pallas_call(
        kernel,
        grid=(Sp // LANES,),
        in_specs=[
            pl.BlockSpec((LANES, B), lambda i: (i, 0)),          # stream block
            pl.BlockSpec((LANES,), lambda i: (i,)),              # counts
            pl.BlockSpec((lut_size,), lambda i: (0,)),           # LUT resident
            pl.BlockSpec((lut_size,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((LANES, max_count), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, max_count), jnp.int32),
        interpret=interpret,
    )(mat, counts.astype(jnp.int32), lut_sym.astype(jnp.int32),
      lut_len.astype(jnp.int32))
    return out[:S]
