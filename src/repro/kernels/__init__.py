"""Pallas TPU kernels for EntroLLM's two compute hot-spots:

* ``huffman_decode`` — the paper's parallel entropy decoder (lane-parallel LUT
  walk; the paper's own custom-kernel contribution);
* ``dequant_matmul`` — fused int8/int4 dequantize-matmul for the serving path
  (keeps the HBM stream at 1 or 0.5 bytes/param in the memory-bound decode
  phase — the bandwidth saving Table II measures).

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles used by
the per-kernel allclose sweeps in tests/.
"""
from . import dequant_matmul, huffman_decode, ops, ref
