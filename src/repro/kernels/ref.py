"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dequant_matmul_ref(x: jax.Array, wq: jax.Array, scale, zero, *,
                       int4: bool = False, out_dtype=jnp.bfloat16) -> jax.Array:
    """Same math as kernels.dequant_matmul, straight-line jnp."""
    if int4:
        lo = (wq & 0x0F)
        hi = (wq >> 4)
        K2, N = wq.shape
        wsym = jnp.stack([lo, hi], axis=1).reshape(K2 * 2, N)
    else:
        wsym = wq
    scale = jnp.asarray(scale, jnp.float32).reshape(1, -1)
    zero = jnp.asarray(zero, jnp.float32).reshape(1, -1)
    # dequant in f32, bf16 only as the dot operand — the exact contract the
    # Pallas kernel implements (kernels/dequant_matmul.py)
    w = (wsym.astype(jnp.float32) * scale + zero).astype(jnp.bfloat16)
    return jnp.dot(x.astype(jnp.bfloat16), w,
                   preferred_element_type=jnp.float32).astype(out_dtype)


def decode_streams_ref(mat: np.ndarray, counts: np.ndarray, lut_sym: np.ndarray,
                       lut_len: np.ndarray, max_len: int) -> np.ndarray:
    """Host-side multi-stream oracle (shared with core.bitstream)."""
    from repro.core.bitstream import decode_streams
    return decode_streams(mat, counts, lut_sym, lut_len, max_len)


def fused_decode_matmul_ref(x: jax.Array, mat: np.ndarray, table, scale, zero,
                            *, seg_symbols: int, K: int, N: int) -> jax.Array:
    """Numpy-decode oracle for ``kernels.fused_decode_matmul``.

    Decodes the (S, B) lane matrix serially on the host through the numpy
    backend (itself oracle-checked against ``bitstream.decode_serial`` /
    ``decode_serial_tans`` by ``tests/test_decode_oracle_parity.py``), then
    applies the *exact* dequant + dot ops of ``models.layers.deq``/``matmul``
    — so the jit fused impl must match it bit for bit, and the Pallas impls
    allclose (bf16 MXU accumulation order differs inside the kernel).
    """
    from repro.core.decode_backends import get_backend
    mat = np.asarray(mat)
    counts = np.full(mat.shape[0], seg_symbols, np.int64)
    dec = get_backend("numpy").decode_table(table, mat, counts,
                                            max_count=seg_symbols)
    q = jnp.asarray(np.asarray(dec).reshape(K, N).astype(np.uint8))
    dt = x.dtype
    wd = q.astype(dt) * jnp.asarray(scale).astype(dt) \
        + jnp.asarray(zero).astype(dt)
    return x @ wd
