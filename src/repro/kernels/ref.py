"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dequant_matmul_ref(x: jax.Array, wq: jax.Array, scale, zero, *,
                       int4: bool = False, out_dtype=jnp.bfloat16) -> jax.Array:
    """Same math as kernels.dequant_matmul, straight-line jnp."""
    if int4:
        lo = (wq & 0x0F)
        hi = (wq >> 4)
        K2, N = wq.shape
        wsym = jnp.stack([lo, hi], axis=1).reshape(K2 * 2, N)
    else:
        wsym = wq
    scale = jnp.asarray(scale, jnp.float32).reshape(1, -1)
    zero = jnp.asarray(zero, jnp.float32).reshape(1, -1)
    # dequant in f32, bf16 only as the dot operand — the exact contract the
    # Pallas kernel implements (kernels/dequant_matmul.py)
    w = (wsym.astype(jnp.float32) * scale + zero).astype(jnp.bfloat16)
    return jnp.dot(x.astype(jnp.bfloat16), w,
                   preferred_element_type=jnp.float32).astype(out_dtype)


def decode_streams_ref(mat: np.ndarray, counts: np.ndarray, lut_sym: np.ndarray,
                       lut_len: np.ndarray, max_len: int) -> np.ndarray:
    """Host-side multi-stream oracle (shared with core.bitstream)."""
    from repro.core.bitstream import decode_streams
    return decode_streams(mat, counts, lut_sym, lut_len, max_len)
