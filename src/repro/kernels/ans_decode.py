"""Pallas TPU kernel: multi-stream tANS (FSE-style) decode.

The ``tans`` twin of :mod:`repro.kernels.huffman_decode` — same lock-step
lane-per-segment structure (a block of ``LANES`` streams advances one symbol
per iteration), with the carried per-lane ANS state replacing the Huffman
window peek as the table index.  VMEM holds three ``2^table_log`` int32
tables (48 KiB at the default ``table_log=12``) instead of Huffman's two.

Loop body per lane (matches ``core.bitstream.decode_serial_tans`` exactly):

    sym   = tab_sym[state]
    nb    = tab_bits[state]                       # 0..table_log fresh bits
    fresh = top nb bits of the table_log-bit window at bitpos
    state = tab_base[state] + fresh;  bitpos += nb

Streams begin with a 16-bit initial-state header
(``bitstream.TANS_STATE_HEADER_BITS``); guard bytes make the 32-bit window
load always in-bounds.  The kernel is embarrassingly parallel across stream
blocks (grid dim 0), exactly like the Huffman kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitstream import TANS_STATE_HEADER_BITS

from .huffman_decode import LANES


def _tans_kernel(mat_ref, counts_ref, sym_ref, bits_ref, base_ref, out_ref, *,
                 table_log: int, max_count: int):
    """One grid step: decode LANES tANS streams, max_count symbols each."""
    d = mat_ref[...].astype(jnp.uint32)           # (LANES, B) stream bytes
    counts = counts_ref[...]                      # (LANES,)
    tab_sym = sym_ref[...]                        # (2^table_log,)
    tab_bits = bits_ref[...]
    tab_base = base_ref[...]
    mask = jnp.uint32((1 << table_log) - 1)
    lanes = jnp.arange(d.shape[0])

    def step(k, carry):
        st, bitpos, out = carry
        sym = tab_sym[st]
        nb = tab_bits[st]
        byte = (bitpos >> 3).astype(jnp.int32)
        w = (
            (d[lanes, byte] << 24)
            | (d[lanes, byte + 1] << 16)
            | (d[lanes, byte + 2] << 8)
            | d[lanes, byte + 3]
        )
        shift = (32 - table_log - (bitpos & 7)).astype(jnp.uint32)
        peek = (w >> shift) & mask
        fresh = (peek >> (table_log - nb).astype(jnp.uint32)).astype(jnp.int32)
        active = k < counts
        out = out.at[:, k].set(jnp.where(active, sym, 0))
        st = jnp.where(active, tab_base[st] + fresh, st)
        bitpos = jnp.where(active, bitpos + nb, bitpos)
        return st, bitpos, out

    st0 = ((d[:, 0] << 8) | d[:, 1]).astype(jnp.int32)
    bitpos0 = jnp.full((d.shape[0],), TANS_STATE_HEADER_BITS, jnp.int32)
    out0 = jnp.zeros((d.shape[0], max_count), jnp.int32)
    _, _, out = jax.lax.fori_loop(0, max_count, step, (st0, bitpos0, out0))
    out_ref[...] = out


def tans_decode_supported(table_log: int = 8) -> bool:
    """Probe whether the tANS kernel *compiles* on this host (same protocol as
    ``huffman_decode.pallas_decode_supported``: tiny real decode, cached)."""
    key = int(table_log)
    if key in _SUPPORTED_CACHE:
        return _SUPPORTED_CACHE[key]
    try:
        import numpy as np
        from repro.core.codecs.rans import RansCodeTable
        table = RansCodeTable(np.array([3, 1], dtype=np.int64), bits=1,
                              table_log=table_log)
        syms = np.array([1, 0, 0], np.uint8)
        stream, _ = table.encode(syms)
        out = decode_streams_tans_pallas(
            jnp.asarray(stream[None, :]), jnp.asarray([3], jnp.int32),
            jnp.asarray(table.tab_sym), jnp.asarray(table.tab_bits),
            jnp.asarray(table.tab_base),
            table_log=table.table_log, max_count=3, interpret=False)
        ok = bool((np.asarray(out)[0] == syms).all())
    except Exception:
        ok = False
    _SUPPORTED_CACHE[key] = ok
    return ok


_SUPPORTED_CACHE: dict = {}


@functools.partial(jax.jit,
                   static_argnames=("table_log", "max_count", "interpret"))
def decode_streams_tans_pallas(mat: jax.Array, counts: jax.Array,
                               tab_sym: jax.Array, tab_bits: jax.Array,
                               tab_base: jax.Array, *, table_log: int,
                               max_count: int,
                               interpret: bool = False) -> jax.Array:
    """mat: (S, B) uint8 guard-padded tANS streams (headers included);
    counts: (S,) int32.  Returns (S, max_count) int32 symbols.
    """
    S, B = mat.shape
    Sp = -(-S // LANES) * LANES
    if Sp != S:
        mat = jnp.pad(mat, ((0, Sp - S), (0, 0)))
        counts = jnp.pad(counts, (0, Sp - S))
    tab_size = tab_sym.shape[0]

    kernel = functools.partial(_tans_kernel, table_log=table_log,
                               max_count=max_count)
    out = pl.pallas_call(
        kernel,
        grid=(Sp // LANES,),
        in_specs=[
            pl.BlockSpec((LANES, B), lambda i: (i, 0)),          # stream block
            pl.BlockSpec((LANES,), lambda i: (i,)),              # counts
            pl.BlockSpec((tab_size,), lambda i: (0,)),           # tables resident
            pl.BlockSpec((tab_size,), lambda i: (0,)),
            pl.BlockSpec((tab_size,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((LANES, max_count), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, max_count), jnp.int32),
        interpret=interpret,
    )(mat, counts.astype(jnp.int32), tab_sym.astype(jnp.int32),
      tab_bits.astype(jnp.int32), tab_base.astype(jnp.int32))
    return out[:S]
