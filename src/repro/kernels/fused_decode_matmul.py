"""Fused entropy-decode → dequantize → matmul (the end of the HBM round trip).

Compressed-resident serving (``serving/resident.py``) decodes each layer's
QT triples into a dense double-buffered slot before its matmuls, so the
dense weights still transit HBM once per layer.  This module removes that
round trip: a :class:`FusedQT` handle keeps one tensor's layer slice as the
*packed lane matrix* of its encoded segments (plus the codec's decode
tables and the layer's scale/zero), and ``fused_decode_matmul(x, fq)``
decodes weight tiles straight into the matmul's K-loop — on TPU inside a
Pallas kernel's VMEM scratch, elsewhere through the jit lock-step decoders.

Geometry (the tile-alignment contract ``core.scheduler.fused_tile_reason``
checks): the layer slice is (K, N) symbols stored row-major as S uniform
segments of ``seg`` symbols each, with ``seg % N == 0`` — so every lane
boundary coincides with a matmul K-tile boundary and a decoded lane block
``(lanes, seg)`` reshapes losslessly to ``(lanes * seg/N, N)``.  Containers
are written with fixed segment budgets, so real stacked tensors satisfy
this whenever ``K*N % seg == 0`` (ragged tails fall back to the unfused
per-layer decode path).

Implementations (``FusedQT.impl``, probed like decode-backend capability):

* ``"jax"`` — in-graph :func:`repro.core.decode_jax.decode_streams_jax` /
  ``decode_streams_tans_jax`` followed by the *exact* ops of
  ``models.layers.deq`` + ``matmul`` (bf16 dequant, same dot).  Decoded
  symbols are exact integers, so this path is **bit-identical** to the
  unfused QT slot on any host — the property the differential harness
  (``tests/differential/``) asserts end to end.
* ``"pallas"`` — one kernel: grid over K-tiles, each program decodes its
  lane block with the lock-step loop (prefix or tANS), dequantizes in
  bf16 inside VMEM, and accumulates into an f32 scratch.  Compiled-only;
  :func:`fused_supported` probes it like ``pallas_decode_supported``.
* ``"pallas-interpret"`` — the same kernel interpreted (CPU differential
  testing only; never auto-picked).

The numpy oracle every implementation is checked against is
:func:`repro.kernels.ref.fused_decode_matmul_ref`.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128             # lane cap per program instance (one VREG row)
FUSED_IMPLS = ("pallas", "jax", "pallas-interpret")


def lanes_per_tile(n_lanes: int, cap: int = LANES) -> int:
    """Largest divisor of ``n_lanes`` not exceeding ``cap`` — the per-program
    lane-block height (divisor, so the grid tiles the lanes exactly)."""
    for c in range(min(n_lanes, cap), 0, -1):
        if n_lanes % c == 0:
            return c
    return 1


@jax.tree_util.register_pytree_node_class
class FusedQT:
    """A compressed weight handle the matmul can consume directly.

    Children (traced): ``mat`` — the (S, B) uint8 guard-padded lane matrix
    of the layer slice's segments; ``tabs`` — the codec's decode-table
    arrays (prefix: lut_sym, lut_len; tans: tab_sym, tab_bits, tab_base);
    ``scale``/``zero`` — the layer's dequant affine (broadcastable against
    (K, N), exactly what the unfused QT slot carries).

    Aux (static, shapes the kernel): ``family`` ("prefix"/"tans"),
    ``tbits`` (peek_bits / table_log), ``seg`` symbols per lane, the dense
    (K, N) geometry, the quantizer ``bits`` (provenance only — symbols
    decode to uint8 regardless), and ``impl``.

    Registered as a pytree so handles flow through jitted serving blocks
    like any weight leaf; the static aux is identical across layers of one
    tensor, so the per-layer block functions retrace once, not per layer.
    """

    def __init__(self, mat, tabs, scale, zero, *, family: str, tbits: int,
                 seg: int, K: int, N: int, bits: int, impl: str):
        self.mat = mat
        self.tabs = tuple(tabs)
        self.scale = scale
        self.zero = zero
        self.family = family
        self.tbits = int(tbits)
        self.seg = int(seg)
        self.K = int(K)
        self.N = int(N)
        self.bits = int(bits)
        self.impl = impl

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.K, self.N)

    def tree_flatten(self):
        return ((self.mat, self.tabs, self.scale, self.zero),
                (self.family, self.tbits, self.seg, self.K, self.N,
                 self.bits, self.impl))

    @classmethod
    def tree_unflatten(cls, aux, children):
        mat, tabs, scale, zero = children
        family, tbits, seg, K, N, bits, impl = aux
        return cls(mat, tabs, scale, zero, family=family, tbits=tbits,
                   seg=seg, K=K, N=N, bits=bits, impl=impl)

    def __repr__(self):
        return (f"FusedQT({self.family}{self.bits}, K={self.K}, N={self.N}, "
                f"seg={self.seg}, lanes={self.mat.shape[0]}, "
                f"impl={self.impl!r})")


def default_fused_impl(family: str = "prefix") -> str:
    """Capability pick, mirroring the decode-backend registry's auto rule:
    the compiled Pallas kernel where it probes, the jit path elsewhere."""
    return "pallas" if fused_supported(family) else "jax"


def build_fused_qt(table, mat, scale, zero, *, seg_symbols: int, K: int,
                   N: int, bits: int, impl: Optional[str] = None) -> FusedQT:
    """Build a :class:`FusedQT` from a codec table + packed lane matrix.

    ``mat`` rows are the layer slice's segments in symbol order, each
    holding exactly ``seg_symbols`` symbols (uniform — the tile-alignment
    contract), guard-padded as by ``bitstream.pack_streams``.
    """
    mat = jnp.asarray(mat, jnp.uint8)
    S = mat.shape[0]
    if S * seg_symbols != K * N:
        raise ValueError(
            f"lane matrix holds {S} x {seg_symbols} symbols; dense geometry "
            f"needs {K} x {N}")
    if seg_symbols % N:
        raise ValueError(
            f"segment of {seg_symbols} symbols does not tile rows of {N}")
    a = table.decode_arrays()
    if table.kernel == "prefix":
        tabs = (jnp.asarray(a["lut_sym"], jnp.int32),
                jnp.asarray(a["lut_len"], jnp.int32))
        tbits = int(table.peek_bits)
    elif table.kernel == "tans":
        tabs = (jnp.asarray(a["tab_sym"], jnp.int32),
                jnp.asarray(a["tab_bits"], jnp.int32),
                jnp.asarray(a["tab_base"], jnp.int32))
        tbits = int(table.table_log)
    else:
        raise ValueError(f"unknown kernel family {table.kernel!r}")
    if impl is None:
        impl = default_fused_impl(table.kernel)
    if impl not in FUSED_IMPLS:
        raise ValueError(f"unknown fused impl {impl!r}; one of {FUSED_IMPLS}")
    return FusedQT(mat, tabs, jnp.asarray(scale), jnp.asarray(zero),
                   family=table.kernel, tbits=tbits, seg=int(seg_symbols),
                   K=int(K), N=int(N), bits=int(bits), impl=impl)


# ------------------------------------------------------------------ jax impl

def _decode_lanes_jax(fq: FusedQT) -> jax.Array:
    """In-graph decode of the full lane matrix -> (K, N) uint8 symbols."""
    from repro.core.decode_jax import (decode_streams_jax,
                                       decode_streams_tans_jax)
    S = fq.mat.shape[0]
    counts = jnp.full((S,), fq.seg, jnp.int32)
    if fq.family == "prefix":
        dec = decode_streams_jax(fq.mat, counts, fq.tabs[0], fq.tabs[1],
                                 max_len=fq.tbits, max_count=fq.seg)
    else:
        dec = decode_streams_tans_jax(fq.mat, counts, fq.tabs[0], fq.tabs[1],
                                      fq.tabs[2], table_log=fq.tbits,
                                      max_count=fq.seg)
    return dec.reshape(fq.K, fq.N).astype(jnp.uint8)


def _fused_jax(x: jax.Array, fq: FusedQT) -> jax.Array:
    # the exact op sequence of layers.deq(QT, x.dtype) + layers.matmul —
    # decoded symbols are exact integers, so this is bit-identical to the
    # unfused slot path (the differential harness's core claim)
    q = _decode_lanes_jax(fq)
    dt = x.dtype
    wd = q.astype(dt) * fq.scale.astype(dt) + fq.zero.astype(dt)
    return x @ wd


# --------------------------------------------------------------- pallas impl

def _fused_prefix_kernel(x_ref, mat_ref, sym_ref, len_ref, scale_ref,
                         zero_ref, o_ref, acc_ref, *, seg: int, max_len: int,
                         n_k: int):
    """One K-tile: decode the lane block, dequantize in VMEM, accumulate."""
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = mat_ref[...].astype(jnp.uint32)            # (lpt, B) stream bytes
    lut_sym = sym_ref[...]
    lut_len = len_ref[...]
    mask = jnp.uint32((1 << max_len) - 1)
    lanes = jnp.arange(d.shape[0])

    def step(t, carry):
        bitpos, out = carry
        byte = (bitpos >> 3).astype(jnp.int32)
        w = (
            (d[lanes, byte] << 24)
            | (d[lanes, byte + 1] << 16)
            | (d[lanes, byte + 2] << 8)
            | d[lanes, byte + 3]
        )
        shift = (32 - max_len - (bitpos & 7)).astype(jnp.uint32)
        peek = ((w >> shift) & mask).astype(jnp.int32)
        # uniform lane counts == seg: every lane is active every step
        out = out.at[:, t].set(lut_sym[peek])
        return bitpos + lut_len[peek], out

    bitpos0 = jnp.zeros((d.shape[0],), jnp.int32)
    out0 = jnp.zeros((d.shape[0], seg), jnp.int32)
    _, syms = jax.lax.fori_loop(0, seg, step, (bitpos0, out0))
    _deq_accumulate(x_ref, syms, scale_ref, zero_ref, acc_ref)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _fused_tans_kernel(x_ref, mat_ref, sym_ref, bits_ref, base_ref, scale_ref,
                       zero_ref, o_ref, acc_ref, *, seg: int, table_log: int,
                       n_k: int):
    from repro.core.bitstream import TANS_STATE_HEADER_BITS
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = mat_ref[...].astype(jnp.uint32)
    tab_sym = sym_ref[...]
    tab_bits = bits_ref[...]
    tab_base = base_ref[...]
    mask = jnp.uint32((1 << table_log) - 1)
    lanes = jnp.arange(d.shape[0])

    def step(t, carry):
        st, bitpos, out = carry
        sym = tab_sym[st]
        nb = tab_bits[st]
        byte = (bitpos >> 3).astype(jnp.int32)
        w = (
            (d[lanes, byte] << 24)
            | (d[lanes, byte + 1] << 16)
            | (d[lanes, byte + 2] << 8)
            | d[lanes, byte + 3]
        )
        shift = (32 - table_log - (bitpos & 7)).astype(jnp.uint32)
        peek = (w >> shift) & mask
        fresh = (peek >> (table_log - nb).astype(jnp.uint32)).astype(jnp.int32)
        out = out.at[:, t].set(sym)
        return tab_base[st] + fresh, bitpos + nb, out

    st0 = ((d[:, 0] << 8) | d[:, 1]).astype(jnp.int32)
    bitpos0 = jnp.full((d.shape[0],), TANS_STATE_HEADER_BITS, jnp.int32)
    out0 = jnp.zeros((d.shape[0], seg), jnp.int32)
    _, _, syms = jax.lax.fori_loop(0, seg, step, (st0, bitpos0, out0))
    _deq_accumulate(x_ref, syms, scale_ref, zero_ref, acc_ref)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _deq_accumulate(x_ref, syms, scale_ref, zero_ref, acc_ref):
    """Shared tail: (lpt, seg) symbols -> (bk, N) bf16 weights -> MXU.

    Dequant happens in bf16 — the serving contract of ``layers.deq`` (the
    unfused slot path this kernel replaces), unlike ``dequant_matmul``'s
    f32 grid: the fused path's comparison target is the QT slot, not the
    f32 oracle, so it mirrors the slot's arithmetic.
    """
    N = acc_ref.shape[1]
    lpt, seg = syms.shape
    q = syms.reshape(lpt * (seg // N), N)          # row-major: (bk, N)
    w = (q.astype(jnp.bfloat16) * scale_ref[...].astype(jnp.bfloat16)
         + zero_ref[...].astype(jnp.bfloat16))
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.bfloat16), w,
                            preferred_element_type=jnp.float32)


def _fused_pallas(x: jax.Array, fq: FusedQT, *, interpret: bool) -> jax.Array:
    lead = x.shape[:-1]
    x2 = x.reshape(-1, fq.K)
    M = x2.shape[0]
    Mp = -(-M // 8) * 8                      # sublane-align the batch rows
    if Mp != M:
        x2 = jnp.pad(x2, ((0, Mp - M), (0, 0)))
    S, B = fq.mat.shape
    lpt = lanes_per_tile(S)
    bk = lpt * (fq.seg // fq.N)              # K rows decoded per program
    n_k = S // lpt

    scale2 = jnp.broadcast_to(
        jnp.asarray(fq.scale, jnp.float32).reshape(1, -1),
        (1, fq.N) if jnp.size(fq.scale) > 1 else (1, 1))
    zero2 = jnp.broadcast_to(
        jnp.asarray(fq.zero, jnp.float32).reshape(1, -1),
        (1, fq.N) if jnp.size(fq.zero) > 1 else (1, 1))
    sn = scale2.shape[1]

    if fq.family == "prefix":
        kernel = functools.partial(_fused_prefix_kernel, seg=fq.seg,
                                   max_len=fq.tbits, n_k=n_k)
    else:
        kernel = functools.partial(_fused_tans_kernel, seg=fq.seg,
                                   table_log=fq.tbits, n_k=n_k)
    tab_specs = [pl.BlockSpec((t.shape[0],), lambda k: (0,))
                 for t in fq.tabs]
    out = pl.pallas_call(
        kernel,
        grid=(n_k,),
        in_specs=[
            pl.BlockSpec((Mp, bk), lambda k: (0, k)),       # x K-slab
            pl.BlockSpec((lpt, B), lambda k: (k, 0)),       # lane block
            *tab_specs,                                     # tables resident
            pl.BlockSpec((1, sn), lambda k: (0, 0)),
            pl.BlockSpec((1, sn), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((Mp, fq.N), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, fq.N), x.dtype),
        scratch_shapes=[pltpu.VMEM((Mp, fq.N), jnp.float32)],
        interpret=interpret,
    )(x2, fq.mat, *fq.tabs, scale2, zero2)
    return out[:M].reshape(*lead, fq.N)


# ------------------------------------------------------------------ dispatch

def fused_decode_matmul(x: jax.Array, fq: FusedQT) -> jax.Array:
    """``x @ dequant(decode(fq))`` without materializing the dense weight in
    HBM.  ``x``: (..., K); returns (..., N) in ``x.dtype``."""
    if fq.impl == "jax":
        return _fused_jax(x, fq)
    if fq.impl == "pallas":
        return _fused_pallas(x, fq, interpret=False)
    if fq.impl == "pallas-interpret":
        return _fused_pallas(x, fq, interpret=True)
    raise ValueError(f"unknown fused impl {fq.impl!r}; one of {FUSED_IMPLS}")


# -------------------------------------------------------------------- probes

_FUSED_CACHE: dict = {}


def _probe_case(family: str):
    """A small but tile-shaped case (N=128 so the compiled kernel sees a
    full-lane minor dim): returns (x, FusedQT-without-impl args)."""
    from repro.core.bitstream import GUARD_BYTES, pack_streams, pow2_bucket
    from repro.core.codecs import get_codec
    rng = np.random.default_rng(7)
    K, N, seg = 16, 128, 128
    sym = rng.integers(0, 16, K * N).astype(np.uint8)
    freqs = np.bincount(sym, minlength=256).astype(np.int64)
    codec = "huffman" if family == "prefix" else "rans"
    table = get_codec(codec).build(freqs, 8, max_code_len=12)
    streams = [table.encode(sym[i: i + seg])[0]
               for i in range(0, sym.size, seg)]
    width = pow2_bucket(max(GUARD_BYTES, max(s.size for s in streams)), 64)
    mat, _ = pack_streams(streams, min_width=width)
    x = jnp.asarray(rng.normal(size=(16, K)), jnp.bfloat16)
    scale = np.float32(0.01) * np.ones((1, 1), np.float32)
    zero = np.zeros((1, 1), np.float32)
    return x, table, mat, scale, zero, seg, K, N


def fused_supported(family: str = "prefix") -> bool:
    """Probe whether the fused kernel *compiles* on this host (the ``fused``
    capability the backend registry reports): runs the probe case with
    ``interpret=False`` and checks the result against the jit path.  Cached
    after the first call, like ``pallas_decode_supported``."""
    if family in _FUSED_CACHE:
        return _FUSED_CACHE[family]
    try:
        x, table, mat, scale, zero, seg, K, N = _probe_case(family)
        fq = build_fused_qt(table, mat, scale, zero, seg_symbols=seg, K=K,
                            N=N, bits=8, impl="pallas")
        got = np.asarray(_fused_pallas(x, fq, interpret=False), np.float32)
        want = np.asarray(_fused_jax(x, fq), np.float32)
        ok = np.allclose(got, want, atol=1e-2, rtol=1e-2)
    except Exception:
        ok = False
    _FUSED_CACHE[family] = ok
    return ok
