"""Pallas TPU kernel: fused dequantize-and-matmul for EntroLLM serving.

The decode phase of LLM inference is memory-bandwidth bound: every step reads
all weight bytes once.  Keeping weights as uint8 symbols (or packed uint4
nibbles) in HBM and dequantizing *inside the matmul's VMEM tiles* halves (or
quarters) the dominant HBM term; the MXU still sees bf16 operands.

Tiling: grid (M/bm, N/bn, K/bk), K innermost ("arbitrary" = sequential) so an
f32 VMEM scratch accumulates partial products — the standard TPU matmul
skeleton.  Block shapes default to MXU-aligned (128, 128) with bk=512 for a
weight tile of 512*128 = 64 KiB uint8 (32 KiB packed uint4) — comfortably
inside the ~16 MiB VMEM with double buffering.

Quantization grid matches ``core.quant`` (the paper's mixed scheme):
``w = q * scale + zero``; scale/zero are per-tensor scalars or per-output-
channel (N,) rows.  Both are resident in VMEM as (1, bn) tiles.

int4 path: two nibbles per byte along K — ``wq_packed[k//2, n]`` holds
k-even in the low nibble, k-odd in the high nibble (see ``ops.pack_nibbles``).
The kernel unpacks a (bk//2, bn) byte tile into a (bk, bn) symbol tile with
shifts and interleave — no gathers, VPU-friendly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, wq_ref, scale_ref, zero_ref, o_ref, acc_ref, *,
               n_k: int, int4: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                 # (bm, bk) bf16
    if int4:
        packed = wq_ref[...]                       # (bk//2, bn) uint8
        lo = (packed & 0x0F).astype(jnp.float32)   # even k
        hi = (packed >> 4).astype(jnp.float32)     # odd k
        half, bn = packed.shape
        wsym = jnp.stack([lo, hi], axis=1).reshape(half * 2, bn)
    else:
        wsym = wq_ref[...].astype(jnp.float32)     # (bk, bn)
    scale = scale_ref[...]                         # (1, bn) or (1, 1) f32
    zero = zero_ref[...]
    # dequant in f32 (matches kernels/ref.py); only the MXU operand is bf16 —
    # the quantization grid q*scale+zero is not exactly representable in bf16
    # and per-term bf16 rounding drifts past the kernel-vs-oracle tolerance
    w = (wsym * scale + zero).astype(jnp.bfloat16)  # fused dequant in VMEM
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "int4", "interpret", "out_dtype"))
def dequant_matmul(x: jax.Array, wq: jax.Array, scale: jax.Array,
                   zero: jax.Array, *, bm: int = 128, bn: int = 128,
                   bk: int = 512, int4: bool = False, interpret: bool = True,
                   out_dtype=jnp.bfloat16) -> jax.Array:
    """x: (M, K) bf16; wq: (K, N) uint8 or (K//2, N) packed uint4.

    scale/zero: scalars, (N,), or (1, N) — broadcast against output channels.
    Returns (M, N) in ``out_dtype``.
    """
    M, K = x.shape
    N = wq.shape[1]
    K_w = wq.shape[0] * (2 if int4 else 1)
    assert K == K_w, (x.shape, wq.shape, int4)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk

    scale2 = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(1, -1),
                              (1, N) if jnp.size(scale) > 1 else (1, 1))
    zero2 = jnp.broadcast_to(jnp.asarray(zero, jnp.float32).reshape(1, -1),
                             (1, N) if jnp.size(zero) > 1 else (1, 1))
    per_channel = scale2.shape[1] == N
    sn = bn if per_channel else 1
    s_index = (lambda i, j, k: (0, j)) if per_channel else (lambda i, j, k: (0, 0))

    wq_rows = bk // 2 if int4 else bk

    kernel = functools.partial(_mm_kernel, n_k=n_k, int4=int4)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((wq_rows, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, sn), s_index),
            pl.BlockSpec((1, sn), s_index),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.bfloat16), wq, scale2, zero2)
