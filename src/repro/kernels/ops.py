"""Jitted public wrappers around the Pallas kernels + packing utilities.

``interpret=None`` resolves by capability probe: the compiled kernel is used
whenever it lowers on this host (``huffman_decode.pallas_decode_supported``),
and interpret mode is only the fallback when compilation is impossible
(CPU-only containers); callers can force either.  All wrappers fall back to
the jnp oracle when ``REPRO_DISABLE_PALLAS=1`` (escape hatch for debugging).
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .dequant_matmul import dequant_matmul as _dequant_matmul_pallas
from .huffman_decode import decode_streams_pallas, pallas_decode_supported


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas() -> bool:
    return os.environ.get("REPRO_DISABLE_PALLAS", "0") != "1"


def pack_nibbles(q: np.ndarray) -> np.ndarray:
    """(K, N) uint8 symbols < 16 -> (K//2, N) packed bytes (even k low nibble)."""
    assert q.shape[0] % 2 == 0, q.shape
    lo = q[0::2]
    hi = q[1::2]
    return (lo | (hi << 4)).astype(np.uint8)


def unpack_nibbles(p: np.ndarray) -> np.ndarray:
    K2, N = p.shape
    out = np.empty((K2 * 2, N), np.uint8)
    out[0::2] = p & 0x0F
    out[1::2] = p >> 4
    return out


def dequant_matmul(x: jax.Array, wq: jax.Array, scale, zero, *,
                   int4: bool = False, interpret: Optional[bool] = None,
                   block: Tuple[int, int, int] = (128, 128, 512)) -> jax.Array:
    """Fused dequant matmul with automatic padding to block multiples."""
    if not _use_pallas():
        return ref.dequant_matmul_ref(x, wq, scale, zero, int4=int4)
    interpret = (not _on_tpu()) if interpret is None else interpret
    bm, bn, bk = block
    M, K = x.shape
    N = wq.shape[1]
    Mp, Np, Kp = (-(-M // bm) * bm, -(-N // bn) * bn, -(-K // bk) * bk)
    xpad = jnp.pad(x, ((0, Mp - M), (0, Kp - K))) if (Mp, Kp) != (M, K) else x
    if int4:
        # packed rows: K/2 bytes along axis 0; pad at the end keeps alignment
        wpad = jnp.pad(wq, ((0, (Kp - K) // 2), (0, Np - N))) \
            if (Kp, Np) != (K, N) else wq
    else:
        wpad = jnp.pad(wq, ((0, Kp - K), (0, Np - N))) if (Kp, Np) != (K, N) else wq
    if jnp.size(scale) > 1:
        scale = jnp.pad(jnp.asarray(scale, jnp.float32).reshape(-1), (0, Np - N))
        zero = jnp.pad(jnp.asarray(zero, jnp.float32).reshape(-1), (0, Np - N))
    out = _dequant_matmul_pallas(xpad, wpad, scale, zero, bm=bm, bn=bn, bk=bk,
                                 int4=int4, interpret=interpret)
    return out[:M, :N]


def huffman_decode(mat: jax.Array, counts: jax.Array, lut_sym: jax.Array,
                   lut_len: jax.Array, *, max_len: int, max_count: int,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Multi-stream Huffman decode (see kernels.huffman_decode)."""
    if not _use_pallas():
        import numpy as _np
        return jnp.asarray(ref.decode_streams_ref(
            _np.asarray(mat), _np.asarray(counts), _np.asarray(lut_sym),
            _np.asarray(lut_len), max_len))
    if interpret is None:
        interpret = not pallas_decode_supported()
    return decode_streams_pallas(mat, counts, lut_sym, lut_len,
                                 max_len=max_len, max_count=max_count,
                                 interpret=interpret)
