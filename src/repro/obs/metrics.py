"""Metrics registry: named counters, gauges, and streaming histograms with
JSON-lines snapshot export (docs/OBSERVABILITY.md has the name catalog).

Zero dependencies (stdlib only) so every layer — ``core/``, ``serving/``,
``launch/`` — can record against the process-default registry without import
cycles.  Recording is always on: a counter increment is a dict lookup and an
add (~100ns), cheap enough that instrumentation points never need an
enabled-check; *export* is what the caller opts into (``--metrics-out``).

Three metric kinds, Prometheus-shaped:

* :class:`Counter` — monotonically increasing, labeled
  (``registry.counter("queue.shed").inc(outcome="queue_full")``).
* :class:`Gauge` — last-written value per label set.
* :class:`Histogram` — streaming distribution: exact while small, then the
  P² (Jain & Chlamtac 1985) single-pass quantile estimator per tracked
  quantile — O(1) memory per quantile, no stored samples, p50/p90/p99
  accurate to ~1% on smooth distributions (asserted vs numpy by
  ``tests/test_obs.py``).

Every metric guards **label cardinality** (``MAX_LABEL_SETS`` distinct label
sets): a label that encodes an unbounded value (request id, timestamp) is an
instrumentation bug that would silently grow memory forever, so the guard
raises instead.

:func:`percentile` is the ONE shared exact-percentile rule (linear
interpolation, numpy's default) used by every benchmark and the launcher —
it replaces the index-biased ``lat[int(len(lat)*0.99)]`` one-offs so all
reported percentiles agree.

:class:`Lifecycle` records the per-request event chain of the continuous
engine (queued → admitted → prefill → first-token → done), exported as one
JSON line per request.
"""
from __future__ import annotations

import json
import math
import threading
import time
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, \
    Tuple

# distinct label sets per metric before the cardinality guard trips
MAX_LABEL_SETS = 64


def percentile(xs: Sequence[float], p: float) -> float:
    """Exact percentile with linear interpolation (numpy's default rule).

    ``p`` in [0, 100].  Empty input returns NaN.  This is the shared helper
    the launcher and benchmarks report through — the old
    ``sorted(xs)[int(len(xs) * 0.99)]`` pattern is biased low for small N
    (16 requests: index 15*0.99=15 truncates to the p94 order statistic at
    best, and ``min(len-1, ...)`` clamps make it the max), while linear
    interpolation agrees with ``np.percentile`` to float precision.
    """
    if not 0.0 <= p <= 100.0:
        raise ValueError(f"percentile p must be in [0, 100], got {p}")
    xs = sorted(xs)
    if not xs:
        return float("nan")
    if len(xs) == 1:
        return float(xs[0])
    rank = (len(xs) - 1) * (p / 100.0)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    if lo == hi:
        return float(xs[lo])
    frac = rank - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


class P2Quantile:
    """P² single-pass quantile estimator (Jain & Chlamtac, CACM 1985).

    Five markers track (min, q/2-ish, q, (1+q)/2-ish, max); marker heights
    adjust toward their ideal positions with a piecewise-parabolic update.
    Exact until 5 observations have arrived.
    """

    __slots__ = ("q", "n", "heights", "pos", "want", "dpos")

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = q
        self.n = 0
        self.heights: List[float] = []
        self.pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self.want = [1.0, 1 + 2 * q, 1 + 4 * q, 3 + 2 * q, 5.0]
        self.dpos = [0.0, q / 2, q, (1 + q) / 2, 1.0]

    def observe(self, x: float) -> None:
        self.n += 1
        if len(self.heights) < 5:
            self.heights.append(float(x))
            self.heights.sort()
            return
        h = self.heights
        if x < h[0]:
            h[0] = float(x)
            k = 0
        elif x >= h[4]:
            h[4] = float(x)
            k = 3
        else:
            k = 0
            while x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            self.pos[i] += 1.0
        for i in range(5):
            self.want[i] += self.dpos[i]
        for i in (1, 2, 3):
            d = self.want[i] - self.pos[i]
            if (d >= 1 and self.pos[i + 1] - self.pos[i] > 1) or \
                    (d <= -1 and self.pos[i - 1] - self.pos[i] < -1):
                d = 1.0 if d > 0 else -1.0
                # piecewise-parabolic (P²) height update; fall back to
                # linear when the parabola would cross a neighbor
                hp = h[i] + d / (self.pos[i + 1] - self.pos[i - 1]) * (
                    (self.pos[i] - self.pos[i - 1] + d)
                    * (h[i + 1] - h[i]) / (self.pos[i + 1] - self.pos[i])
                    + (self.pos[i + 1] - self.pos[i] - d)
                    * (h[i] - h[i - 1]) / (self.pos[i] - self.pos[i - 1]))
                if not h[i - 1] < hp < h[i + 1]:
                    j = i + int(d)
                    hp = h[i] + d * (h[j] - h[i]) / (self.pos[j] - self.pos[i])
                h[i] = hp
                self.pos[i] += d

    @property
    def value(self) -> float:
        if not self.heights:
            return float("nan")
        if len(self.heights) < 5 or self.n < 5:
            return percentile(self.heights, self.q * 100.0)
        return self.heights[2]


def _label_key(labels: Mapping[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class CardinalityError(ValueError):
    """A metric saw more distinct label sets than MAX_LABEL_SETS — some
    label is carrying an unbounded value (request id, offset, timestamp)."""


class _Metric:
    kind = "?"

    def __init__(self, name: str, help: str = "",
                 max_label_sets: int = MAX_LABEL_SETS):
        self.name = name
        self.help = help
        self.max_label_sets = max_label_sets
        self._lock = threading.Lock()
        self._children: Dict[Tuple[Tuple[str, str], ...], Any] = {}

    def _child(self, labels: Mapping[str, Any]) -> Any:
        key = _label_key(labels)
        child = self._children.get(key)
        if child is None:
            if len(self._children) >= self.max_label_sets:
                raise CardinalityError(
                    f"metric {self.name!r} exceeded {self.max_label_sets} "
                    f"distinct label sets (offending labels: {dict(labels)}) "
                    f"— a label is likely carrying an unbounded value")
            child = self._children[key] = self._new_child()
        return child

    def _new_child(self) -> Any:
        raise NotImplementedError

    def snapshot_rows(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    def _rows(self) -> Iterable[Tuple[Dict[str, str], Any]]:
        with self._lock:
            items = list(self._children.items())
        for key, child in items:
            yield dict(key), child


class Counter(_Metric):
    kind = "counter"

    def _new_child(self) -> List[float]:
        return [0.0]

    def inc(self, value: float = 1.0, **labels: Any) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(inc {value})")
        with self._lock:
            self._child(labels)[0] += value

    def value(self, **labels: Any) -> float:
        with self._lock:
            child = self._children.get(_label_key(labels))
            return child[0] if child else 0.0

    def total(self) -> float:
        with self._lock:
            return sum(c[0] for c in self._children.values())

    def snapshot_rows(self) -> List[Dict[str, Any]]:
        return [dict(name=self.name, kind=self.kind, labels=labels,
                     value=child[0]) for labels, child in self._rows()]


class Gauge(_Metric):
    kind = "gauge"

    def _new_child(self) -> List[float]:
        return [float("nan")]

    def set(self, value: float, **labels: Any) -> None:
        with self._lock:
            self._child(labels)[0] = float(value)

    def value(self, **labels: Any) -> float:
        with self._lock:
            child = self._children.get(_label_key(labels))
            return child[0] if child else float("nan")

    def snapshot_rows(self) -> List[Dict[str, Any]]:
        return [dict(name=self.name, kind=self.kind, labels=labels,
                     value=child[0]) for labels, child in self._rows()]


class _HistChild:
    __slots__ = ("count", "sum", "min", "max", "quantiles")

    def __init__(self, qs: Sequence[float]):
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.quantiles = {q: P2Quantile(q) for q in qs}


class Histogram(_Metric):
    """Streaming distribution; tracked quantiles default to p50/p90/p99."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 quantiles: Sequence[float] = (0.5, 0.9, 0.99),
                 max_label_sets: int = MAX_LABEL_SETS):
        super().__init__(name, help, max_label_sets)
        self.quantiles = tuple(quantiles)

    def _new_child(self) -> _HistChild:
        return _HistChild(self.quantiles)

    def observe(self, value: float, **labels: Any) -> None:
        value = float(value)
        with self._lock:
            c = self._child(labels)
            c.count += 1
            c.sum += value
            c.min = min(c.min, value)
            c.max = max(c.max, value)
            for est in c.quantiles.values():
                est.observe(value)

    def quantile(self, q: float, **labels: Any) -> float:
        with self._lock:
            c = self._children.get(_label_key(labels))
            if c is None or q not in c.quantiles:
                return float("nan")
            return c.quantiles[q].value

    def count(self, **labels: Any) -> int:
        with self._lock:
            c = self._children.get(_label_key(labels))
            return c.count if c else 0

    def snapshot_rows(self) -> List[Dict[str, Any]]:
        rows = []
        for labels, c in self._rows():
            row = dict(name=self.name, kind=self.kind, labels=labels,
                       count=c.count, sum=c.sum,
                       min=c.min if c.count else None,
                       max=c.max if c.count else None)
            for q, est in c.quantiles.items():
                row[f"p{q * 100:g}"] = est.value if c.count else None
            rows.append(row)
        return rows


class Lifecycle:
    """One request's event chain (queued → admitted → prefill → first-token
    → done), exported as one JSON line.  Timestamps are monotonic-clock
    seconds, the same clock the :class:`~repro.serving.batching.request.
    Request` stamps use, so engine timestamps can be recorded verbatim."""

    __slots__ = ("rid", "labels", "events")

    def __init__(self, rid: int, **labels: Any):
        self.rid = rid
        self.labels = {k: str(v) for k, v in labels.items()}
        self.events: List[Tuple[str, float]] = []

    def event(self, name: str, t: Optional[float] = None) -> None:
        self.events.append((name, time.monotonic() if t is None else float(t)))

    def label(self, **labels: Any) -> None:
        self.labels.update((k, str(v)) for k, v in labels.items())

    def row(self) -> Dict[str, Any]:
        return dict(name="request.lifecycle", kind="lifecycle", rid=self.rid,
                    labels=dict(self.labels),
                    events=[[n, t] for n, t in self.events])


class Registry:
    """Named metrics + request lifecycles, with get-or-create accessors.

    Accessors are idempotent (``registry.counter("queue.shed")`` at two call
    sites share one metric) and kind-checked (asking for an existing name as
    a different kind raises — silent kind drift would corrupt snapshots).
    """

    # requests outlive any single snapshot; bound the retained lifecycles so
    # a long-lived engine cannot grow host memory through its own telemetry
    MAX_LIFECYCLES = 100_000

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}
        self._lifecycles: List[Lifecycle] = []
        self.dropped_lifecycles = 0

    def _get(self, name: str, kind: type, **kw: Any) -> Any:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = kind(name, **kw)
            elif not isinstance(m, kind):
                raise TypeError(f"metric {name!r} is a {m.kind}, not a "
                                f"{kind.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  quantiles: Sequence[float] = (0.5, 0.9, 0.99)) -> Histogram:
        return self._get(name, Histogram, help=help, quantiles=quantiles)

    def lifecycle(self, rid: int, **labels: Any) -> Lifecycle:
        lc = Lifecycle(rid, **labels)
        with self._lock:
            if len(self._lifecycles) >= self.MAX_LIFECYCLES:
                self.dropped_lifecycles += 1
            else:
                self._lifecycles.append(lc)
        return lc

    @property
    def lifecycles(self) -> List[Lifecycle]:
        with self._lock:
            return list(self._lifecycles)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> List[Dict[str, Any]]:
        """All metric children + lifecycles as plain dict rows (the
        JSON-lines schema ``scripts/check_trace.py`` validates)."""
        with self._lock:
            metrics = [self._metrics[n] for n in sorted(self._metrics)]
            lifecycles = list(self._lifecycles)
        rows: List[Dict[str, Any]] = []
        for m in metrics:
            rows.extend(m.snapshot_rows())
        rows.extend(lc.row() for lc in lifecycles)
        return rows

    def write_jsonl(self, path: str) -> int:
        """One JSON object per line; returns the number of rows written.
        Non-finite floats serialize as null (strict-JSON consumers)."""

        def clean(v: Any) -> Any:
            if isinstance(v, float) and not math.isfinite(v):
                return None
            if isinstance(v, dict):
                return {k: clean(x) for k, x in v.items()}
            if isinstance(v, list):
                return [clean(x) for x in v]
            return v

        rows = self.snapshot()
        with open(path, "w") as f:
            for row in rows:
                f.write(json.dumps(clean(row)) + "\n")
        return len(rows)


# ---------------------------------------------------------------------------
# process-default registry: what unqualified instrumentation records against

_default = Registry()
_default_lock = threading.Lock()


def default_registry() -> Registry:
    return _default


def reset() -> Registry:
    """Swap in a fresh default registry (serve runs and tests isolate with
    this; instrumentation sites look the registry up per call, so nothing
    holds a stale reference)."""
    global _default
    with _default_lock:
        _default = Registry()
        return _default


def counter(name: str, help: str = "") -> Counter:
    return _default.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return _default.gauge(name, help)


def histogram(name: str, help: str = "",
              quantiles: Sequence[float] = (0.5, 0.9, 0.99)) -> Histogram:
    return _default.histogram(name, help, quantiles)


def lifecycle(rid: int, **labels: Any) -> Lifecycle:
    return _default.lifecycle(rid, **labels)


class LegacyMetricsView(Mapping):
    """Read-through alias from the historical ad-hoc metric-dict keys to
    registry gauges (deprecated surface — new code should read the registry
    names directly; docs/OBSERVABILITY.md maps old key → canonical name).

    Behaves like the dict it replaces (``m["decode_tok_per_s"]``, ``.get``,
    iteration), but the values come from the registry: each gauge is read
    once at construction, so the view is a stable record of *that* call even
    after a later serve overwrites the gauges (callers compare views from
    two runs side by side).  Non-gauge entries (e.g. the resolved backend
    name) ride in ``extra``.
    """

    def __init__(self, registry: Registry, alias: Mapping[str, str],
                 extra: Optional[Mapping[str, Any]] = None):
        self._registry = registry
        self._alias = dict(alias)              # old key -> canonical gauge
        self._extra = dict(extra or {})
        self._frozen = {k: registry.gauge(name).value()
                        for k, name in self._alias.items()}

    def __getitem__(self, key: str) -> Any:
        if key in self._extra:
            return self._extra[key]
        return self._frozen[key]

    def __iter__(self):
        seen = set(self._extra)
        yield from self._extra
        for k in self._alias:
            if k not in seen:
                yield k

    def __len__(self) -> int:
        return len(set(self._alias) | set(self._extra))

    def __repr__(self) -> str:
        return f"LegacyMetricsView({dict(self)!r})"
