"""Instrumentation-point catalog: the span and metric names each serving
mode is REQUIRED to emit.

``scripts/check_trace.py --expect MODE`` validates an emitted trace/metrics
pair against this catalog and fails if any registered point produced zero
events — the CI ``obs-smoke`` guard against instrumentation silently rotting
(a renamed span or a refactor that drops a call site would otherwise pass
every functional test).

Add new instrumentation here when it is a *contract* (the overlap report or
a dashboard depends on it); purely informational spans can stay uncatalogued.
Names must match docs/OBSERVABILITY.md's catalog — ``tests/test_obs.py``
cross-checks that every point listed here appears in the doc.
"""
from __future__ import annotations

from typing import Dict, List

# span names (trace file) and metric names (metrics JSON-lines) that MUST
# appear at least once for a serve in the given mode
EXPECTED_POINTS: Dict[str, Dict[str, List[str]]] = {
    # lockstep Engine.generate, --resident compressed --fused.  NOTE: no
    # decode.exec_step / decode.symbols here — when every matmul tensor is
    # fused, the entropy decode happens inside the jitted kernel (payload
    # handles), so the host-side scheduler decode never runs; the per-layer
    # slot still materializes the carve-out views (resident.slot_tensors).
    "resident-fused-lockstep": {
        "spans": [
            "serve.prefill",
            "serve.decode_step",
            "serve.layer",
            "resident.decode",
            "resident.consume_wait",
        ],
        "metrics": [
            "load.decode_load_s",
            "serve.decode_tok_per_s",
            "serve.e2e_tok_per_s",
            "serve.decode_step_s",
            "resident.prefetch_issued",
            "resident.fused_tensors",
            "resident.slot_tensors",
        ],
    },
    # lockstep Engine.generate, --resident compressed (unfused)
    "resident-lockstep": {
        "spans": [
            "serve.prefill",
            "serve.decode_step",
            "serve.layer",
            "resident.decode",
            "resident.consume_wait",
            "decode.exec_step",
        ],
        "metrics": [
            "load.decode_load_s",
            "serve.decode_tok_per_s",
            "serve.decode_step_s",
            "resident.prefetch_issued",
            "resident.slot_tensors",
            "decode.symbols",
        ],
    },
    # lockstep Engine.generate, --resident dense (streaming load)
    "dense-lockstep": {
        "spans": [
            "load.stream",
            "serve.prefill",
            "serve.decode_step",
            "decode.chunk",
        ],
        "metrics": [
            "load.decode_load_s",
            "load.time_to_first_weight_s",
            "serve.decode_tok_per_s",
            "serve.decode_step_s",
            "decode.symbols",
        ],
    },
    # ContinuousEngine (--batch-slots), dense residency
    "continuous": {
        "spans": [
            "serve.step",
            "serve.admit_chunk",
            "serve.decode_batch",
        ],
        "metrics": [
            "queue.depth",
            "queue.submitted",
            "queue.wait_s",
            "slots.occupied",
            "slots.inserts",
            "request.ttft_s",
            "request.latency_s",
        ],
    },
    # ContinuousEngine with the paged KV cache (--batch-slots --kv-spec).
    # kv.shared_hits only fires on a prefix hit, so this mode's smoke
    # traffic MUST replay shared system prompts (--prefix-sharing traffic
    # does) — a serve that never hits is indistinguishable from sharing
    # having gone dark.
    "paged-continuous": {
        "spans": [
            "serve.step",
            "serve.admit_chunk",
            "serve.decode_batch",
            "kv.admit",
        ],
        "metrics": [
            "queue.depth",
            "queue.submitted",
            "queue.wait_s",
            "slots.occupied",
            "slots.inserts",
            "request.ttft_s",
            "request.latency_s",
            "kv.resident_bytes",
            "kv.blocks_free",
            "kv.shared_hits",
        ],
    },
}
