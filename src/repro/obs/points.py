"""Instrumentation-point catalog: the span and metric names each serving
mode is REQUIRED to emit.

``scripts/check_trace.py --expect MODE`` validates an emitted trace/metrics
pair against this catalog and fails if any registered point produced zero
events — the CI ``obs-smoke`` guard against instrumentation silently rotting
(a renamed span or a refactor that drops a call site would otherwise pass
every functional test).

Add new instrumentation here when it is a *contract* (the overlap report or
a dashboard depends on it); everything else must be registered in
``INFORMATIONAL_POINTS`` below — the ``catalog-sync`` checker
(``scripts/check_static.py``) fails on any emit site whose name appears in
neither set, and on any cataloged name with no remaining emit site.
Names must match docs/OBSERVABILITY.md's catalog — ``tests/test_obs.py``
cross-checks that every point listed here appears in the doc.
"""
from __future__ import annotations

from typing import Dict, List

# span names (trace file) and metric names (metrics JSON-lines) that MUST
# appear at least once for a serve in the given mode
EXPECTED_POINTS: Dict[str, Dict[str, List[str]]] = {
    # lockstep Engine.generate, --resident compressed --fused.  NOTE: no
    # decode.exec_step / decode.symbols here — when every matmul tensor is
    # fused, the entropy decode happens inside the jitted kernel (payload
    # handles), so the host-side scheduler decode never runs; the per-layer
    # slot still materializes the carve-out views (resident.slot_tensors).
    "resident-fused-lockstep": {
        "spans": [
            "serve.prefill",
            "serve.decode_step",
            "serve.layer",
            "resident.decode",
            "resident.consume_wait",
        ],
        "metrics": [
            "load.decode_load_s",
            "serve.decode_tok_per_s",
            "serve.e2e_tok_per_s",
            "serve.decode_step_s",
            "resident.prefetch_issued",
            "resident.fused_tensors",
            "resident.slot_tensors",
        ],
    },
    # lockstep Engine.generate, --resident compressed (unfused)
    "resident-lockstep": {
        "spans": [
            "serve.prefill",
            "serve.decode_step",
            "serve.layer",
            "resident.decode",
            "resident.consume_wait",
            "decode.exec_step",
        ],
        "metrics": [
            "load.decode_load_s",
            "serve.decode_tok_per_s",
            "serve.decode_step_s",
            "resident.prefetch_issued",
            "resident.slot_tensors",
            "decode.symbols",
        ],
    },
    # lockstep Engine.generate, --resident dense (streaming load)
    "dense-lockstep": {
        "spans": [
            "load.stream",
            "serve.prefill",
            "serve.decode_step",
            "decode.chunk",
        ],
        "metrics": [
            "load.decode_load_s",
            "load.time_to_first_weight_s",
            "serve.decode_tok_per_s",
            "serve.decode_step_s",
            "decode.symbols",
        ],
    },
    # ContinuousEngine (--batch-slots), dense residency
    "continuous": {
        "spans": [
            "serve.step",
            "serve.admit_chunk",
            "serve.decode_batch",
        ],
        "metrics": [
            "queue.depth",
            "queue.submitted",
            "queue.wait_s",
            "slots.occupied",
            "slots.inserts",
            "request.ttft_s",
            "request.latency_s",
        ],
    },
    # FleetDriver (--replicas N): the fleet boundary plus every replica's
    # continuous-engine points.  fleet.pump only spans when the intake has
    # requests to place and fleet.dispatch only on a routing decision, so
    # any fleet serve that moves traffic must emit both — a router refactor
    # that silently stops routing (or stops recording it) goes dark here.
    "fleet-continuous": {
        "spans": [
            "fleet.pump",
            "fleet.dispatch",
            "serve.step",
            "serve.admit_chunk",
            "serve.decode_batch",
        ],
        "metrics": [
            "fleet.submitted",
            "fleet.dispatched",
            "fleet.replicas_up",
            "fleet.queue_depth",
            "queue.depth",
            "queue.submitted",
            "queue.wait_s",
            "slots.occupied",
            "slots.inserts",
            "request.ttft_s",
            "request.latency_s",
        ],
    },
    # ContinuousEngine with the paged KV cache (--batch-slots --kv-spec).
    # kv.shared_hits only fires on a prefix hit, so this mode's smoke
    # traffic MUST replay shared system prompts (--prefix-sharing traffic
    # does) — a serve that never hits is indistinguishable from sharing
    # having gone dark.
    "paged-continuous": {
        "spans": [
            "serve.step",
            "serve.admit_chunk",
            "serve.decode_batch",
            "kv.admit",
        ],
        "metrics": [
            "queue.depth",
            "queue.submitted",
            "queue.wait_s",
            "slots.occupied",
            "slots.inserts",
            "request.ttft_s",
            "request.latency_s",
            "kv.resident_bytes",
            "kv.blocks_free",
            "kv.shared_hits",
        ],
    },
}

# Best-effort instrumentation: emitted by some code path but required by no
# serving mode (mode-dependent, probe-only, or benchmark-oriented).  The
# catalog-sync checker keeps this bidirectional with the emit sites: every
# name here has at least one emit site, every emit site is in exactly one
# of EXPECTED_POINTS / INFORMATIONAL_POINTS.
INFORMATIONAL_POINTS: Dict[str, List[str]] = {
    "spans": [
        "fleet.handoff_adopt",      # disaggregated fleets only
        "fleet.handoff_encode",
        "kv.cold_decode",           # only with a cold-tier codec configured
        "kv.cold_encode",
        "resident.prefetch_issue",
    ],
    "metrics": [
        "decode.calls",             # scheduler chunking detail
        "fleet.admission_rejects",  # admission-gate vetoes (chaos/test seam)
        "fleet.handoff_bytes",      # disaggregated fleets only
        "fleet.handoffs",
        "fleet.redrives",           # only after a replica failure
        "fleet.shed",               # only under overload / failures
        "kv.cold_evictions",        # cold tier / eviction pressure only
        "kv.cold_restores",
        "kv.dropped_evictions",
        "kv.shared_misses",         # zero on non-sharing traffic
        "load.decodes",
        "queue.shed",               # only under overload
        "requests.finished",
        "resident.fused_fallback",  # zero when every tensor fuses
        "resident.prefetch_hit",    # hit/wait split of consume_wait
        "resident.prefetch_wait",
        "serve.prefill_s",          # lockstep wall-clock breakdown
        "serve.decode_s",
        "serve.ttft_s",
        "serve.tokens",
        "slots.compactions",        # only when fragmentation triggers
        "slots.releases",
    ],
}
