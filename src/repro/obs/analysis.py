"""Trace analysis: decode/compute overlap and prefetch stalls from a
Chrome/Perfetto ``trace_event`` file.

The compressed-resident execution model (docs/SERVING.md) claims layer
*l+1*'s entropy decode rides under layer *l*'s compute.  In the trace that
claim is three span families:

* ``resident.decode`` — the worker thread actually decoding a layer;
* ``resident.consume_wait`` — the main thread blocked in ``get(l)`` because
  the prefetch had not finished (the *stall*: decode time NOT hidden);
* ``serve.decode_step`` / ``serve.prefill`` — the main thread's step window
  (dispatching blocks + waiting on the device).

:func:`overlap_report` reduces them to two headline numbers:

* **prefetch stall time** — total ``resident.consume_wait`` duration: the
  wall-clock the serving loop spent waiting for weight decode.
* **decode/compute overlap fraction** — the share of worker decode time
  that ran while the main thread was *busy* (inside a step span but not in
  a consume wait), i.e. decode that was actually hidden under compute
  dispatch.  1.0 = perfectly hidden; 0.0 = every decoded byte stalled the
  step loop (what ``prefetch=False`` or a decode-bound host degrades to).

Everything here is stdlib + pure interval arithmetic, shared by
``benchmarks/overlap_report.py`` and ``tests/test_obs.py``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Sequence, Tuple

Interval = Tuple[float, float]


def load_trace_events(path: str) -> List[Dict[str, Any]]:
    """Read a Chrome ``trace_event`` JSON file (object or bare array)."""
    with open(path) as f:
        data = json.load(f)
    events = data["traceEvents"] if isinstance(data, dict) else data
    if not isinstance(events, list):
        raise ValueError(f"{path}: traceEvents is not a list")
    return events


def span_intervals(events: Iterable[Dict[str, Any]],
                   name: str) -> List[Interval]:
    """[start, end) microsecond intervals of every ``ph="X"`` span named
    ``name``, in start order."""
    out = [(float(e["ts"]), float(e["ts"]) + float(e.get("dur", 0.0)))
           for e in events
           if e.get("ph") == "X" and e.get("name") == name]
    return sorted(out)


def union(intervals: Sequence[Interval]) -> List[Interval]:
    """Merge overlapping/adjacent intervals."""
    merged: List[Interval] = []
    for a, b in sorted(intervals):
        if merged and a <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], b))
        else:
            merged.append((a, b))
    return merged


def subtract(base: Sequence[Interval],
             holes: Sequence[Interval]) -> List[Interval]:
    """``base`` minus ``holes`` (both may overlap internally)."""
    base = union(base)
    holes = union(holes)
    out: List[Interval] = []
    hi = 0
    for a, b in base:
        cur = a
        while hi < len(holes) and holes[hi][1] <= cur:
            hi += 1
        j = hi
        while j < len(holes) and holes[j][0] < b:
            ha, hb = holes[j]
            if ha > cur:
                out.append((cur, ha))
            cur = max(cur, hb)
            j += 1
        if cur < b:
            out.append((cur, b))
    return out


def total(intervals: Sequence[Interval]) -> float:
    return sum(b - a for a, b in union(intervals))


def intersect_total(xs: Sequence[Interval], ys: Sequence[Interval]) -> float:
    """Total length of the pairwise intersection of two interval sets."""
    xs, ys = union(xs), union(ys)
    i = j = 0
    out = 0.0
    while i < len(xs) and j < len(ys):
        a = max(xs[i][0], ys[j][0])
        b = min(xs[i][1], ys[j][1])
        if b > a:
            out += b - a
        if xs[i][1] < ys[j][1]:
            i += 1
        else:
            j += 1
    return out


def overlap_report(events: Iterable[Dict[str, Any]],
                   *, decode_span: str = "resident.decode",
                   wait_span: str = "resident.consume_wait",
                   step_spans: Sequence[str] = ("serve.decode_step",
                                                "serve.prefill")
                   ) -> Dict[str, float]:
    """Decode/compute overlap metrics from a trace's events (see module
    docstring).  All times in seconds; ``overlap_fraction`` in [0, 1]
    (NaN when the trace holds no decode spans)."""
    events = list(events)
    decode = span_intervals(events, decode_span)
    waits = span_intervals(events, wait_span)
    steps: List[Interval] = []
    for name in step_spans:
        steps.extend(span_intervals(events, name))
    busy = subtract(steps, waits)       # main thread driving, not stalled
    decode_total = total(decode)
    overlapped = intersect_total(decode, busy)
    frac = overlapped / decode_total if decode_total > 0 else float("nan")
    return {
        "decode_s": decode_total / 1e6,
        "stall_s": total(waits) / 1e6,
        "step_s": total(steps) / 1e6,
        "overlapped_decode_s": overlapped / 1e6,
        "overlap_fraction": min(1.0, frac) if frac == frac else frac,
        "n_decode_spans": float(len(decode)),
        "n_wait_spans": float(len(waits)),
    }
