"""Serving observability: structured tracing, a metrics registry, and trace
analysis — zero dependencies beyond the stdlib (docs/OBSERVABILITY.md).

* :mod:`repro.obs.trace` — nested, thread-aware spans; Chrome/Perfetto
  ``trace_event`` export and a plain-text span tree.  Off by default;
  ``trace.enable()`` installs the process-global tracer the instrumented
  layers record against (``--trace-out`` in the launcher).
* :mod:`repro.obs.metrics` — named counters / gauges / streaming histograms
  (P² quantiles) with a label-cardinality guard, JSON-lines snapshots, the
  shared exact :func:`~repro.obs.metrics.percentile` helper, and per-request
  lifecycle records.  Always recording (cheap); exported on demand
  (``--metrics-out``).
* :mod:`repro.obs.analysis` — interval arithmetic over an emitted trace:
  decode/compute overlap fraction and prefetch stall time
  (``benchmarks/overlap_report.py``).
* :mod:`repro.obs.points` — the per-serving-mode catalog of required
  instrumentation points (``scripts/check_trace.py --expect``).

The cardinal rule: observability is a **pure observer**.  No instrumentation
may change what the serving stack computes — greedy outputs with tracing on
vs off are bit-identical (asserted in ``tests/test_obs.py``) — and no span
may live inside a jitted function body (it would fire at trace time only).
"""
from . import analysis, metrics, points, trace

__all__ = ["analysis", "metrics", "points", "trace"]
