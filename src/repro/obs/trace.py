"""Structured trace recorder: nested, thread-aware spans for the serving
pipeline (docs/OBSERVABILITY.md).

The serving stack's central performance claim — per-layer entropy decode
*overlaps* the previous layer's compute (paper §IV) — is a statement about
concurrent timelines: the worker thread's decode spans against the main
thread's step spans.  This recorder captures exactly that, with three design
constraints:

* **Zero dependencies** — stdlib only (``time``, ``threading``, ``json``),
  so ``core/`` and ``serving/`` can instrument without import cycles or new
  requirements.
* **Pure observer** — spans are host-side wall-clock intervals appended to
  an in-memory list under a lock; nothing in the traced computation changes
  (greedy outputs with tracing on vs off are asserted bit-identical by
  ``tests/test_obs.py`` and ``benchmarks/overlap_report.py``).  Span bodies
  must never run inside a jitted function (they would fire once at trace
  time); instrumentation lives in the Python drivers and call sites only.
* **Cheap when disabled** — the module-level :func:`span` / :func:`instant`
  check one global and return a shared no-op context manager, so compiled
  hot paths pay a dict build + a ``None`` check and nothing else.

Export formats:

* :meth:`Tracer.chrome_trace` / :meth:`Tracer.save` — Chrome
  ``trace_event`` JSON (``{"traceEvents": [...]}``) that loads directly in
  Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``; spans are
  ``ph="X"`` complete events with microsecond timestamps, threads carry
  ``thread_name`` metadata.
* :meth:`Tracer.span_tree` — a plain-text nested tree per thread, for logs
  and quick terminal inspection.

JAX dispatch is asynchronous, so an un-fenced span around a jitted call
measures *dispatch*, not compute.  ``Tracer.sync`` (the ``--trace-sync``
flag) is the opt-in: instrumented call sites consult it and fence
(``jax.block_until_ready``) their outputs so span durations reflect real
device time — at the cost of serializing the very overlap being measured,
which is why it defaults off.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, Iterator, List, Optional

# a runaway loop must not OOM the host through its own observability:
# beyond this many events the tracer drops new spans and counts them
MAX_EVENTS = 1_000_000


class SpanRecord:
    """One finished span: name, category, [t0, t0+dur) in microseconds since
    the tracer epoch, the recording thread, its parent span id, and labels."""

    __slots__ = ("id", "parent", "name", "cat", "ts_us", "dur_us", "tid",
                 "args")

    def __init__(self, id: int, parent: Optional[int], name: str, cat: str,
                 ts_us: float, dur_us: float, tid: int, args: Dict[str, Any]):
        self.id = id
        self.parent = parent
        self.name = name
        self.cat = cat
        self.ts_us = ts_us
        self.dur_us = dur_us
        self.tid = tid
        self.args = args


class _SpanCM:
    """Context manager for one span; grabs its id/parent at ``__enter__`` so
    the tree survives children finishing before (or after) their parent."""

    __slots__ = ("tracer", "name", "cat", "args", "id", "parent", "t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self) -> "_SpanCM":
        tr = self.tracer
        self.id = tr._next_id()
        stack = tr._stack()
        self.parent = stack[-1] if stack else None
        stack.append(self.id)
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        tr = self.tracer
        stack = tr._stack()
        if stack and stack[-1] == self.id:
            stack.pop()
        tr._record(SpanRecord(
            self.id, self.parent, self.name, self.cat,
            (self.t0 - tr._epoch) / 1e3, (t1 - self.t0) / 1e3,
            tr._tid(), self.args))
        return False


class _NullSpan:
    """Shared no-op context manager: what :func:`span` hands out while no
    tracer is enabled.  Stateless, so one instance serves any nesting."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL = _NullSpan()


class Tracer:
    """Thread-safe span recorder with a fixed epoch and per-thread stacks.

    ``sync`` is advisory: the tracer never touches device state itself, but
    instrumented call sites fence their jitted outputs when it is set (see
    module docstring).
    """

    def __init__(self, *, sync: bool = False):
        self.sync = sync
        self._epoch = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._events: List[SpanRecord] = []
        self._instants: List[Dict[str, Any]] = []
        self._ids = 0
        self._local = threading.local()
        self._tids: Dict[int, int] = {}          # thread ident -> small tid
        self._tnames: Dict[int, str] = {}        # small tid -> thread name
        self.dropped = 0

    # ------------------------------------------------------------- recording
    def span(self, name: str, cat: str = "serve", **args: Any) -> _SpanCM:
        return _SpanCM(self, name, cat, args)

    def instant(self, name: str, cat: str = "serve", **args: Any) -> None:
        """A zero-duration marker event (Perfetto ``ph="i"``)."""
        now = (time.perf_counter_ns() - self._epoch) / 1e3
        with self._lock:
            if len(self._instants) + len(self._events) >= MAX_EVENTS:
                self.dropped += 1
                return
            self._instants.append(dict(name=name, cat=cat, ts=now,
                                       tid=self._tid_locked(), args=args))

    def _next_id(self) -> int:
        with self._lock:
            self._ids += 1
            return self._ids

    def _stack(self) -> List[int]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _tid(self) -> int:
        with self._lock:
            return self._tid_locked()

    def _tid_locked(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids[ident] = len(self._tids)
            self._tnames[tid] = threading.current_thread().name
        return tid

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self._events) + len(self._instants) >= MAX_EVENTS:
                self.dropped += 1
                return
            self._events.append(rec)

    # --------------------------------------------------------------- reading
    @property
    def events(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._events)

    def spans(self, name: Optional[str] = None) -> Iterator[SpanRecord]:
        for e in self.events:
            if name is None or e.name == name:
                yield e

    # --------------------------------------------------------------- exports
    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome ``trace_event`` JSON object (Perfetto-loadable)."""
        with self._lock:
            events, instants = list(self._events), list(self._instants)
            tnames = dict(self._tnames)
        out: List[Dict[str, Any]] = []
        for tid, tname in sorted(tnames.items()):
            out.append(dict(name="thread_name", ph="M", pid=1, tid=tid,
                            args={"name": tname}))
        out.append(dict(name="process_name", ph="M", pid=1, tid=0,
                        args={"name": "repro.serving"}))
        for e in events:
            out.append(dict(name=e.name, cat=e.cat or "serve", ph="X",
                            ts=e.ts_us, dur=e.dur_us, pid=1, tid=e.tid,
                            args=dict(e.args)))
        for i in instants:
            out.append(dict(name=i["name"], cat=i["cat"] or "serve", ph="i",
                            ts=i["ts"], pid=1, tid=i["tid"], s="t",
                            args=dict(i["args"])))
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def save(self, path: str) -> int:
        """Write the Chrome trace JSON; returns the number of trace events
        (spans + instants, excluding thread/process metadata)."""
        doc = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return sum(1 for e in doc["traceEvents"] if e["ph"] != "M")

    def span_tree(self) -> str:
        """Plain-text nested span tree, one block per thread, children
        indented under their parent in start order."""
        events = self.events
        by_id = {e.id: e for e in events}
        children: Dict[Optional[int], List[SpanRecord]] = {}
        for e in events:
            # a parent that never finished (still open / dropped) roots
            # its children at the top level rather than losing them
            parent = e.parent if e.parent in by_id else None
            children.setdefault(parent, []).append(e)
        for v in children.values():
            v.sort(key=lambda e: e.ts_us)
        lines: List[str] = []

        def walk(e: SpanRecord, depth: int) -> None:
            args = "".join(f" {k}={v}" for k, v in sorted(e.args.items()))
            lines.append(f"{'  ' * depth}{e.name:<28s} "
                         f"{e.dur_us / 1e3:9.3f}ms{args}")
            for c in children.get(e.id, ()):
                walk(c, depth + 1)

        roots = children.get(None, [])
        for tid in sorted({e.tid for e in roots}):
            name = self._tnames.get(tid, str(tid))
            lines.append(f"[thread {tid}: {name}]")
            for e in roots:
                if e.tid == tid:
                    walk(e, 1)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# module-level switchboard: the ONE global every instrumentation site checks

_active: Optional[Tracer] = None
_active_lock = threading.Lock()


def enable(*, sync: bool = False) -> Tracer:
    """Install (and return) a fresh process-global tracer."""
    global _active
    with _active_lock:
        _active = Tracer(sync=sync)
        return _active


def disable() -> Optional[Tracer]:
    """Uninstall the global tracer; returns it (for export) or None."""
    global _active
    with _active_lock:
        tr, _active = _active, None
        return tr


def get() -> Optional[Tracer]:
    return _active


def enabled() -> bool:
    return _active is not None


def span(name: str, cat: str = "serve", **args: Any):
    """A span against the global tracer, or a shared no-op when disabled."""
    tr = _active
    return tr.span(name, cat, **args) if tr is not None else _NULL


def instant(name: str, cat: str = "serve", **args: Any) -> None:
    tr = _active
    if tr is not None:
        tr.instant(name, cat, **args)


def sync_enabled() -> bool:
    """True when a tracer is active AND asked for fenced spans — the signal
    instrumented jit call sites use to ``block_until_ready`` their outputs
    (the ``--trace-sync`` contract)."""
    tr = _active
    return tr is not None and tr.sync
