"""Training step + loop: grad-accumulation microbatching, donation, metrics.

``make_train_step`` builds the jitted SPMD step used by both the real trainer
(`launch/train.py`) and the dry-run (`launch/dryrun.py`): the same function is
``.lower().compile()``-ed against ShapeDtypeStructs for the roofline table.

Gradient accumulation scans over microbatches *inside* the step (sliced from
the leading batch axis) so the optimizer + collective schedule stays one
program; compute/comm overlap comes from XLA's latency-hiding scheduler —
per-layer reduce-scatters issued inside the backward scan overlap the next
layer's grads (standard FSDP overlap).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api
from . import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt.AdamWConfig = opt.AdamWConfig()
    microbatches: int = 1          # grad-accum splits of the global batch
    grad_accum_dtype: str = "f32"  # "bf16" = memory-tight mode (>=100B archs)
    remat: bool = True
    unroll: int = 1                # layer-scan unroll (dry-run uses n_layers)
    q_block: int = 0               # attention query-block chunking
    grad_compress: bool = False    # int8 gradient all-reduce w/ error feedback
    q8_gather: int = 0             # 0=off | 8 | 4: EntroLLM-compressed FSDP
    #                                weight gathers (STE; see ste_quantize)


def loss_for(cfg: ArchConfig) -> Callable:
    mod = api.build(cfg)
    return mod.loss_fn


def ste_quantize_params(params: Dict[str, Any], bits: int) -> Dict[str, Any]:
    """EntroLLM-compressed FSDP weight movement (beyond-paper, §Perf H2).

    Per-channel (axis-0) mixed symmetric/asymmetric quantization of every big
    matrix to uint8 symbols (packed nibbles for 4-bit) as a QTG 4-tuple the
    models consume via ``deq``.  The quantize is local shard math plus a tiny
    max-reduce; the per-layer FSDP all-gather then moves 1 (or 0.5) bytes per
    parameter instead of 2: the forward path computes only from the symbols
    (the bf16 master is dead code there, so GSPMD gathers the uint8 tensor),
    while ``_ste_deq``'s straight-through backward routes gradients to the
    sharded master (QAT semantics; quality validated at small scale in
    tests/test_integration.py).
    """
    from repro.models.layers import QTG
    from repro.launch.specs import _quantize_pred
    qmax = float((1 << bits) - 1)
    out: Dict[str, Any] = {}
    for name, w in params.items():
        if not _quantize_pred(name, getattr(w, "shape", ())):
            out[name] = w
            continue
        wf = w.astype(jnp.float32)
        red = tuple(range(1, wf.ndim))
        lo = wf.min(axis=red, keepdims=True)
        hi = wf.max(axis=red, keepdims=True)
        single = lo * hi >= 0.0
        absmax = jnp.where(jnp.abs(hi) >= jnp.abs(lo), hi, lo)
        scale = jnp.where(single,
                          jnp.where(absmax == 0.0, 1.0, absmax / qmax),
                          jnp.where(hi == lo, 1.0, (hi - lo) / qmax))
        zero = jnp.where(single, 0.0, lo)
        q = jnp.clip(jnp.round((wf - zero) / scale), 0.0, qmax
                     ).astype(jnp.uint8)
        if bits == 4:
            q = q[..., 0::2] | (q[..., 1::2] << jnp.uint8(4))
        out[name] = QTG(q, scale.astype(jnp.float32),
                        zero.astype(jnp.float32), w)
    return out


def make_train_step(cfg: ArchConfig, tc: TrainConfig) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = loss_for(cfg)

    def microbatch_grads(params, batch):
        def one(p, mb):
            if tc.q8_gather:
                p = ste_quantize_params(p, tc.q8_gather)
            return loss_fn(cfg, p, mb, unroll=tc.unroll,
                           q_block=tc.q_block, remat=tc.remat)

        if tc.microbatches <= 1:
            loss, grads = jax.value_and_grad(one)(params, batch)
            return loss, grads

        def slice_mb(i, x):
            mbs = x.shape[0] // tc.microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mbs, mbs, 0)

        def body(carry, i):
            loss_acc, grad_acc = carry
            mb = jax.tree.map(partial(slice_mb, i), batch)
            loss, grads = jax.value_and_grad(one)(params, mb)
            grad_acc = jax.tree.map(
                lambda a, g: a + g.astype(a.dtype), grad_acc, grads)
            return (loss_acc + loss, grad_acc), None

        acc_dt = jnp.bfloat16 if tc.grad_accum_dtype == "bf16" else jnp.float32
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        (loss, grads), _ = jax.lax.scan(
            body, (jnp.float32(0.0), zeros), jnp.arange(tc.microbatches))
        inv = 1.0 / tc.microbatches
        return loss * inv, jax.tree.map(
            lambda g: g.astype(jnp.float32) * inv, grads)

    def step(params, opt_state, batch):
        loss, grads = microbatch_grads(params, batch)
        if tc.grad_compress:
            from repro.distributed.grad_compress import compress_decompress
            grads = compress_decompress(grads)
        params, opt_state, metrics = opt.apply_updates(tc.opt, params, grads,
                                                       opt_state)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return step


def train(cfg: ArchConfig, tc: TrainConfig, params, opt_state, data_iter,
          num_steps: int, *, jit_kwargs: Optional[dict] = None,
          hooks: Tuple[Callable, ...] = ()) -> Tuple[Any, Any, Dict]:
    """Host-side loop: feeds batches, runs hooks (checkpoint/watchdog/logging)."""
    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1),
                      **(jit_kwargs or {}))
    history = []
    t0 = time.perf_counter()
    for i in range(num_steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        for h in hooks:
            out = h(i, params, opt_state, metrics)
            if out is not None:                       # hook replaced the state
                params, opt_state = out
        history.append({k: float(v) for k, v in metrics.items()})
    wall = time.perf_counter() - t0
    return params, opt_state, {"history": history, "wall_s": wall,
                               "steps_per_s": num_steps / max(wall, 1e-9)}
