"""AdamW built from scratch (no optax on this box), with two state formats:

* fp32 moments — the standard layout;
* **EntroLLM-quantized moments** (beyond-paper, themed): m/v stored as uint8
  symbols under the paper's mixed symmetric/asymmetric per-block scheme
  (block = last axis groups of 128).  This is what makes the 398B-parameter
  archs trainable inside 16 GB/chip HBM: 12 B/param AdamW drops to ~6 B/param
  (bf16 grads + uint8 m + uint8 v + bf16 params + fp32-rounding via
  stochastic-free deterministic round-to-nearest on the quant grid).
  The quantize/dequantize pair is ``quantize_jnp``-style per-block math — the
  same grid the paper uses for weights, reused for optimizer state.

The optimizer is expressed as a pytree-of-arrays state plus pure functions, so
``jax.jit`` donation and ZeRO sharding of the state work out of the box.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# ------------------------------------------------------------------ schedules

@dataclasses.dataclass(frozen=True)
class Schedule:
    base_lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_ratio: float = 0.1

    def __call__(self, step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(self.warmup_steps, 1)
        prog = (s - self.warmup_steps) / jnp.maximum(
            self.total_steps - self.warmup_steps, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = self.min_ratio + (1 - self.min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.base_lr * jnp.where(s < self.warmup_steps, warm, cos)


# ---------------------------------------------------------- block quantization

_Q8_MIN_SIZE = 1 << 16    # small tensors (norms, biases) keep fp32 moments


def _use_q8(shape) -> bool:
    n = 1
    for d in shape:
        n *= int(d)
    return n >= _Q8_MIN_SIZE and int(shape[-1]) >= 64


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-channel (last-axis) mixed symmetric/asymmetric uint8 quantization.

    Channel-wise rather than flat-128-block on purpose: the moment keeps the
    PARAMETER's shape and sharding, so quantize/dequantize lower to purely
    local math + a tiny per-row reduce.  (A flat `(-1, 128)` blocking reshape
    is sharding-hostile — GSPMD replicates the whole tensor; that mistake cost
    543 GiB/device in the dry-run and is logged in EXPERIMENTS.md §Perf.)
    """
    x = x.astype(jnp.float32)
    lo = x.min(axis=-1, keepdims=True)
    hi = x.max(axis=-1, keepdims=True)
    single = lo * hi >= 0.0
    absmax = jnp.where(jnp.abs(hi) >= jnp.abs(lo), hi, lo)
    s_sym = jnp.where(absmax == 0.0, 1.0, absmax / 255.0)
    s_asym = jnp.where(hi == lo, 1.0, (hi - lo) / 255.0)
    scale = jnp.where(single, s_sym, s_asym)
    zero = jnp.where(single, 0.0, lo)
    q = jnp.clip(jnp.round((x - zero) / scale), 0.0, 255.0).astype(jnp.uint8)
    return q, scale.astype(jnp.float32), zero.astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array, zero: jax.Array, shape) -> jax.Array:
    return q.astype(jnp.float32) * scale + zero


class Q8Moment(NamedTuple):
    q: jax.Array       # uint8, same shape as the parameter
    scale: jax.Array   # f32 (..., 1)
    zero: jax.Array    # f32 (..., 1)


# --------------------------------------------------------------------- AdamW

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    schedule: Schedule = Schedule()
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantized_state: bool = False     # EntroLLM-quantized m/v (uint8 blocks)

    # names that never get weight decay (norms, biases, ssm-sensitive)
    @staticmethod
    def decay_mask(name: str) -> bool:
        lname = name.lower()
        return not any(k in lname for k in ("norm", "bias", "a_log", "dt_", "scale"))


class OptState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


def init_state(cfg: AdamWConfig, params: PyTree) -> OptState:
    def zero_moment(p):
        if cfg.quantized_state and _use_q8(p.shape):
            sshape = tuple(p.shape[:-1]) + (1,)
            return Q8Moment(jnp.zeros(p.shape, jnp.uint8),
                            jnp.ones(sshape, jnp.float32),
                            jnp.zeros(sshape, jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)

    m = jax.tree.map(zero_moment, params)
    v = jax.tree.map(zero_moment, params)
    return OptState(jnp.zeros((), jnp.int32), m, v)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply_updates(cfg: AdamWConfig, params: Dict[str, jax.Array],
                  grads: Dict[str, jax.Array], state: OptState
                  ) -> Tuple[Dict[str, jax.Array], OptState, Dict[str, jax.Array]]:
    """One AdamW step.  params is a flat {name: array} dict (the model format).

    Returns (new_params, new_state, metrics).
    """
    step = state.step + 1
    lr = cfg.schedule(step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_params, new_m, new_v = {}, {}, {}
    for name in params:
        p, g = params[name], grads[name]
        q8 = cfg.quantized_state and _use_q8(p.shape)
        g32 = g.astype(jnp.float32) * scale
        if q8:
            # v is stored in sqrt-space: linear uint8 on sqrt(v) keeps the
            # relative resolution Adam's  m/sqrt(v)  denominator needs (linear
            # uint8 directly on v crushes small entries to 0 and the update
            # explodes — refuted-hypothesis note in EXPERIMENTS.md §Perf).
            mq, vq = state.m[name], state.v[name]
            m32 = _dq8(mq.q, mq.scale, mq.zero, p.shape)
            v32 = _dq8(vq.q, vq.scale, vq.zero, p.shape) ** 2
        else:
            m32, v32 = state.m[name], state.v[name]
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * g32 * g32
        upd = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        if cfg.weight_decay and cfg.decay_mask(name):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_params[name] = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if q8:
            new_m[name] = Q8Moment(*_q8(m32))
            new_v[name] = Q8Moment(*_q8(jnp.sqrt(v32)))
        else:
            new_m[name] = m32
            new_v[name] = v32

    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_m, new_v), metrics


def state_shardings(cfg: AdamWConfig, param_shapes: Dict[str, Any],
                    opt_shardings: Dict[str, Any]) -> Any:
    """Shardings pytree matching :func:`init_state`'s structure.

    fp32 moments inherit the ZeRO rules (``opt_shardings``); quantized moments
    keep the parameter's shape (and thus its sharding), with the per-channel
    scale/zero dropping whatever the rule put on the last axis.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    def moment_shard(name, ns):
        if not (cfg.quantized_state and _use_q8(param_shapes[name])):
            return ns
        mesh = ns.mesh
        ndim = len(param_shapes[name])
        entries = list(ns.spec) + [None] * (ndim - len(ns.spec))
        entries[-1] = None                      # scale/zero last dim is 1
        sspec = P(*entries)
        return Q8Moment(ns, NamedSharding(mesh, sspec),
                        NamedSharding(mesh, sspec))

    m = {n: moment_shard(n, opt_shardings[n]) for n in opt_shardings}
    first = next(iter(opt_shardings.values()))
    scalar = NamedSharding(first.mesh, P())
    return OptState(scalar, m, dict(m))
