from . import optimizer, train_loop
